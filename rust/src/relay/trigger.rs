//! Sequence-aware trigger (§3.2): admit only *at-risk* requests for
//! prefix pre-inference, under bounded HBM footprint and bounded
//! pre-inference load.
//!
//! The trigger runs beside retrieval on lightweight behaviour metadata
//! (prefix length / feature dimension) — never the full sequence.  Its
//! admission budget implements the paper's Eqs. 1–3:
//!
//! ```text
//! (1)  L        = Q_admit · T_life              live caches per instance
//! (2)  L · kv_p99 ≤ r1 · HBM                    survivability
//! (3)  Q_admit ≤ Q_m · M ,  Q_max ≤ Q_m·M·r2·N  load bounds
//! ```
//!
//! Rate limiting uses a token bucket per special instance; the live-cache
//! footprint is tracked through feedback from the HBM cache (`release`).
//!
//! ## Closed-loop adaptive admission ([`AdmissionMode::Adaptive`])
//!
//! The static bounds evaluate Eqs. 1–3 once, from provisioned constants
//! (`kv_p99_bytes`, a fixed `headroom`).  The adaptive controller closes
//! the loop on what the cluster actually observes, from signals that are
//! **decision-synchronous** — derived only from the admission stream
//! itself (estimator outputs, admitted footprints, arrival clocks), never
//! from completion timing — so every execution engine driving the same
//! request sequence reaches bit-identical admission decisions:
//!
//! * **Occupancy-aware footprint bound** — instead of `live · kv_p99 ≤
//!   r1·HBM` with a worst-case constant, the controller tracks the
//!   *observed* per-user ψ footprint of admissions inside a sliding
//!   `T_life` window and admits while the summed distinct-user bytes fit
//!   the `r1·HBM` slice (Eq. 2 applied directly, in bytes).  A hot user
//!   re-admitted within the window holds one footprint, not one per
//!   request — exactly the distinct-live-caches `L` of Eq. 1.
//! * **Adaptive risk margin** — the effective `headroom` moves inside
//!   `[headroom_min, headroom_max]` driven by a windowed P99 of the
//!   metadata latency estimates vs the ranking budget: near-SLO traffic
//!   tightens the margin (more requests classified at-risk and relayed),
//!   an idle budget relaxes it (fewer side-path productions).
//! * **Adaptive admitted rate** — the token-bucket rate moves inside
//!   `[rate_mult_min, rate_mult_max] · Q_m·M` under the same pressure
//!   signal; survivability no longer needs the Eq. 1 rate proxy because
//!   the byte-accurate footprint window enforces it directly.
//!
//! `AdmissionMode::Static` (the default) preserves the original Eqs. 1–3
//! flow decision-for-decision — `tests/cross_engine.rs` pins it across
//! engines and scenarios.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::util::cli::Args;
use crate::util::sharded::ShardedMap;

/// Lightweight per-request behaviour metadata the trigger inspects.
#[derive(Debug, Clone, Copy)]
pub struct BehaviorMeta {
    pub user: u64,
    /// Long-term behaviour prefix length in tokens.
    pub prefix_len: usize,
    /// Feature/embedding dimension.
    pub dim: usize,
}

/// How the admission bounds are derived.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Eqs. 1–3 evaluated once from provisioned constants (the default;
    /// decision-for-decision identical to the pre-adaptive trigger).
    Static,
    /// Closed loop: observed footprints replace `kv_p99_bytes`, and the
    /// risk margin / admitted rate track a windowed load estimate.
    Adaptive,
}

impl AdmissionMode {
    /// The one parse table shared by the CLI flag and the config-file
    /// key, so the layers cannot drift.
    pub fn parse(s: &str) -> Result<AdmissionMode> {
        match s {
            "static" => Ok(AdmissionMode::Static),
            "adaptive" => Ok(AdmissionMode::Adaptive),
            other => bail!("unknown admission mode '{other}' (static | adaptive)"),
        }
    }
}

/// Knobs of the closed-loop admission controller.  All defaults are the
/// static configuration (`mode = Static`), so constructing a
/// [`TriggerConfig`] without touching this block changes nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionConfig {
    pub mode: AdmissionMode,
    /// Initial operating point before the estimator windows warm up.
    /// `None` falls back to [`TriggerConfig::headroom`] /
    /// [`AdmissionConfig::rate_mult_max`]; the per-scenario hook
    /// ([`seed_operating_point`](AdmissionConfig::seed_operating_point))
    /// fills unset values from `ScenarioKind::admission_profile`.
    pub headroom_init: Option<f64>,
    pub rate_mult_init: Option<f64>,
    /// Adaptation band for the effective risk headroom.
    pub headroom_min: f64,
    pub headroom_max: f64,
    /// Adaptation band for the admitted-rate multiplier over `Q_m·M`.
    pub rate_mult_min: f64,
    pub rate_mult_max: f64,
    /// Windowed-estimator sample count (latency + footprint P99s).
    pub est_window: usize,
    /// Footprint-window horizon in µs; `None` ⇒ `T_life` (Eq. 1's own
    /// horizon: a cache admitted longer ago than one lifecycle no longer
    /// occupies the live set).  Values below `T_life` are floored to it
    /// at decision time — a reservation must outlive the cache it
    /// models, or the byte bound stops binding.
    pub window_us: Option<u64>,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            mode: AdmissionMode::Static,
            headroom_init: None,
            rate_mult_init: None,
            headroom_min: 0.5,
            headroom_max: 0.95,
            rate_mult_min: 0.25,
            rate_mult_max: 1.0,
            est_window: 64,
            window_us: None,
        }
    }
}

impl AdmissionConfig {
    pub fn adaptive() -> AdmissionConfig {
        AdmissionConfig { mode: AdmissionMode::Adaptive, ..AdmissionConfig::default() }
    }

    pub fn is_adaptive(&self) -> bool {
        self.mode == AdmissionMode::Adaptive
    }

    pub fn label(&self) -> &'static str {
        match self.mode {
            AdmissionMode::Static => "static",
            AdmissionMode::Adaptive => "adaptive",
        }
    }

    /// Fill the initial operating point from a scenario profile without
    /// overriding explicit CLI/config choices (`Some` wins).
    pub fn seed_operating_point(&mut self, headroom_init: f64, rate_mult_init: f64) {
        self.headroom_init.get_or_insert(headroom_init);
        self.rate_mult_init.get_or_insert(rate_mult_init);
    }

    /// Layer `--admission static|adaptive` and the adaptation knobs over
    /// `default` (shared by the serve, sim/figure and `plan` CLIs, and by
    /// the config-file layer through `config::parse_admission`).
    pub fn from_args(args: &Args, default: &AdmissionConfig) -> Result<AdmissionConfig> {
        let mut cfg = default.clone();
        if let Some(mode) = args.get("admission") {
            cfg.mode = AdmissionMode::parse(mode)?;
        }
        cfg.headroom_min = args.get_f64("headroom-min", cfg.headroom_min)?;
        cfg.headroom_max = args.get_f64("headroom-max", cfg.headroom_max)?;
        cfg.rate_mult_min = args.get_f64("rate-mult-min", cfg.rate_mult_min)?;
        cfg.rate_mult_max = args.get_f64("rate-mult-max", cfg.rate_mult_max)?;
        cfg.est_window = args.get_usize("adapt-window", cfg.est_window)?;
        if args.get("headroom-init").is_some() {
            cfg.headroom_init = Some(args.get_f64("headroom-init", 0.0)?);
        }
        if args.get("rate-mult-init").is_some() {
            cfg.rate_mult_init = Some(args.get_f64("rate-mult-init", 0.0)?);
        }
        let h_ok = 0.0 < cfg.headroom_min
            && cfg.headroom_min <= cfg.headroom_max
            && cfg.headroom_max <= 1.0;
        if !h_ok {
            bail!(
                "admission: need 0 < headroom-min <= headroom-max <= 1 (got {} / {})",
                cfg.headroom_min,
                cfg.headroom_max
            );
        }
        if !(0.0 < cfg.rate_mult_min && cfg.rate_mult_min <= cfg.rate_mult_max) {
            bail!(
                "admission: need 0 < rate-mult-min <= rate-mult-max (got {} / {})",
                cfg.rate_mult_min,
                cfg.rate_mult_max
            );
        }
        if cfg.est_window < 2 {
            bail!("admission: --adapt-window must be at least 2");
        }
        // Explicit operating points must sit inside their bands — a
        // silently clamped flag is a mislabeled experiment.  (Scenario-
        // seeded values are still clamped defensively at decide time.)
        if let Some(h) = cfg.headroom_init {
            if !(cfg.headroom_min..=cfg.headroom_max).contains(&h) {
                bail!(
                    "admission: --headroom-init {h} outside [{}, {}]",
                    cfg.headroom_min,
                    cfg.headroom_max
                );
            }
        }
        if let Some(m) = cfg.rate_mult_init {
            if !(cfg.rate_mult_min..=cfg.rate_mult_max).contains(&m) {
                bail!(
                    "admission: --rate-mult-init {m} outside [{}, {}]",
                    cfg.rate_mult_min,
                    cfg.rate_mult_max
                );
            }
        }
        Ok(cfg)
    }
}

/// Static admission-control parameters (the paper's symbols).
#[derive(Debug, Clone)]
pub struct TriggerConfig {
    /// Ranking-stage P99 budget (≈50 ms in the paper's pipeline).
    pub rank_p99_budget_us: f64,
    /// Risk margin: at-risk iff estimated full inference > headroom·budget.
    pub headroom: f64,
    /// T_life — request lifecycle window (retrieval+preproc+ranking tail).
    pub t_life_us: u64,
    /// kv_p99 — P99 per-user ψ footprint in bytes.
    pub kv_p99_bytes: usize,
    /// Device HBM capacity in bytes.
    pub hbm_bytes: usize,
    /// r1 — HBM fraction reserved for live caches.
    pub r1: f64,
    /// Q_m — sustainable pre-infer throughput per model slot (queries/s).
    pub q_m: f64,
    /// M — concurrent model slots per special instance.
    pub m_slots: usize,
    /// r2 — fraction of ranking instances designated special.
    pub r2: f64,
    /// N — total ranking instances.
    pub n_instances: usize,
    /// Decision-synchronous microbatch window, folded in by the
    /// coordinator from its own `batch_window_us` (the coordinator's
    /// window is the single source of truth — do not set this by hand).
    /// Every admitted request spends up to this long waiting out the
    /// batch former, so the adaptive controller charges it to the
    /// admission latency estimate instead of silently attributing the
    /// wait to compute.  The static path is untouched: Eqs. 1–3 have no
    /// batching term and must keep reproducing the paper exactly.
    pub batch_window_us: u64,
    /// Decision-synchronous worst-case retry budget, folded in by the
    /// coordinator from the fault plan (`FaultConfig::retry_budget_us`,
    /// the same single-source-of-truth rule as `batch_window_us`).  An
    /// admitted request may spend up to this long in exponential-backoff
    /// retries before the degradation ladder fires, so the adaptive
    /// controller charges it to the admission estimate.  Zero whenever
    /// the fault plane is off, keeping fault-free runs decision-bit-
    /// identical to the pre-fault trigger; the static path (Eqs. 1–3)
    /// ignores it either way.
    pub retry_budget_us: u64,
    /// Closed-loop admission knobs; `AdmissionMode::Static` (the
    /// default) reproduces the original Eqs. 1–3 flow exactly.
    pub admission: AdmissionConfig,
}

impl TriggerConfig {
    /// The paper's §3.2 sanity-check configuration.
    pub fn paper_example() -> TriggerConfig {
        TriggerConfig {
            rank_p99_budget_us: 50_000.0,
            headroom: 0.8,
            t_life_us: 300_000,
            kv_p99_bytes: 100 * 1000 * 1000, // ~0.1 GB
            hbm_bytes: 32_000_000_000,
            r1: 0.5,
            q_m: 30.0,
            m_slots: 5,
            r2: 0.1,
            n_instances: 100,
            batch_window_us: 0,
            retry_budget_us: 0,
            admission: AdmissionConfig::default(),
        }
    }

    /// Derived admission limits (Eqs. 1–3).
    pub fn limits(&self) -> AdmissionLimits {
        let l_max = ((self.r1 * self.hbm_bytes as f64) / self.kv_p99_bytes as f64).floor() as usize;
        let q_life = l_max as f64 / (self.t_life_us as f64 / 1e6); // Eq. 1 inverted
        let q_compute = self.q_m * self.m_slots as f64; // Eq. 3, per instance
        let q_admit_max = q_life.min(q_compute);
        let specials = (self.r2 * self.n_instances as f64).round().max(1.0);
        AdmissionLimits { l_max, q_admit_max, q_max_system: q_compute * specials, specials: specials as usize }
    }
}

/// The derived bounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionLimits {
    /// Max simultaneously-live caches per special instance (Eq. 2).
    pub l_max: usize,
    /// Max admitted pre-infer rate per special instance, queries/s.
    pub q_admit_max: f64,
    /// System-wide admitted long-sequence traffic bound, queries/s (Eq. 3).
    pub q_max_system: f64,
    /// Number of special instances (r2·N).
    pub specials: usize,
}

/// Trigger decision for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Full inference comfortably fits the ranking budget — no side path.
    NotAtRisk,
    /// Admitted for prefix pre-inference.
    Admit,
    /// At risk, but the per-instance admitted rate is exhausted.
    RateLimited,
    /// At risk, but live caches would outgrow the r1·HBM slice.
    FootprintLimited,
}

/// Token bucket (rate per second over microsecond timestamps).
#[derive(Debug, Clone)]
pub struct TokenBucket {
    rate_per_us: f64,
    burst: f64,
    tokens: f64,
    last_us: u64,
}

impl TokenBucket {
    pub fn new(rate_per_s: f64, burst: f64) -> TokenBucket {
        TokenBucket { rate_per_us: rate_per_s / 1e6, burst, tokens: burst, last_us: 0 }
    }

    pub fn try_take(&mut self, now_us: u64) -> bool {
        let dt = now_us.saturating_sub(self.last_us) as f64;
        self.last_us = self.last_us.max(now_us);
        self.tokens = (self.tokens + dt * self.rate_per_us).min(self.burst);
        // Grant with a tiny epsilon so repeated fractional refills (e.g.
        // 10 × 0.1) are not lost to fp rounding just below 1.0.
        if self.tokens >= 1.0 - 1e-9 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Retarget the refill rate (adaptive admission).  Time elapsed since
    /// the last `try_take` accrues at the *new* rate on the next take —
    /// a pure function of the call sequence, so engines that replay the
    /// same decision stream stay bit-identical.
    pub fn set_rate(&mut self, rate_per_s: f64) {
        self.rate_per_us = rate_per_s / 1e6;
    }

    pub fn rate_per_s(&self) -> f64 {
        self.rate_per_us * 1e6
    }
}

/// Latency estimator used by the metadata risk test.  Deliberately a
/// boxed fn so the simulator wires in the hardware cost model and tests
/// wire in synthetic estimators.
pub type Estimator = Box<dyn Fn(&BehaviorMeta) -> f64 + Send>;

/// Sliding-window ring with a sorted-copy quantile (the windows are a
/// few dozen entries; the trigger runs once per long request, off the
/// rank hot path — `bench_admission.rs` keeps this honest).
#[derive(Debug, Default)]
struct QuantileRing {
    ring: Vec<f64>,
    next: usize,
}

impl QuantileRing {
    fn push(&mut self, cap: usize, v: f64) {
        let cap = cap.max(2);
        if self.ring.len() < cap {
            self.ring.push(v);
        } else {
            self.next %= cap;
            self.ring[self.next] = v;
            self.next = (self.next + 1) % cap;
        }
    }

    fn len(&self) -> usize {
        self.ring.len()
    }

    fn p99(&self) -> Option<f64> {
        if self.ring.is_empty() {
            return None;
        }
        let mut s = self.ring.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let idx = ((s.len() as f64 * 0.99).ceil() as usize).clamp(1, s.len()) - 1;
        Some(s[idx])
    }
}

/// Closed-loop controller state: the observed-footprint window (Eq. 2 in
/// bytes over distinct users) plus the windowed estimators.  All inputs
/// are decision-synchronous — admission decisions, metadata estimates
/// and arrival clocks — never completion timing, so replaying the same
/// request stream reproduces the same state on every engine.
#[derive(Debug, Default)]
struct AdaptiveState {
    /// Windowed metadata latency estimates (µs) of assessed requests.
    est: QuantileRing,
    /// Windowed observed ψ footprints (bytes) of admitted requests.
    fp: QuantileRing,
    /// user → (last admit µs, footprint bytes) inside the window.
    /// Sharded by user-id hash: at trace scale a single table would
    /// concentrate every probe and resize; every access here is keyed,
    /// so decision order never depends on shard layout.
    window: ShardedMap<(u64, usize)>,
    /// Admission order for pruning; entries whose `(time, user)` no
    /// longer matches `window` are tombstones (the user re-admitted).
    order: VecDeque<(u64, u64)>,
    /// Σ footprint bytes over `window` (distinct users).
    window_bytes: usize,
}

impl AdaptiveState {
    /// Drop admissions older than one window horizon (an entry admitted
    /// at `t` lives through `t + window_us`; saturating arithmetic on
    /// the add side so a `t = 0` admit is not spuriously expired).
    fn prune(&mut self, now: u64, window_us: u64) {
        while let Some(&(t, user)) = self.order.front() {
            if t.saturating_add(window_us) > now {
                break;
            }
            self.order.pop_front();
            if let Some(&(last, bytes)) = self.window.get(user) {
                if last == t {
                    self.window.remove(user);
                    self.window_bytes -= bytes;
                }
            }
        }
    }

    /// Would admitting `user` at `bytes` keep the distinct-user footprint
    /// inside `capacity`?  A user already inside the window holds one
    /// live cache however many in-flight requests it has (Eq. 1's L
    /// counts caches, not requests), so re-admission only charges the
    /// *growth* of its footprint — a user whose prefix lengthened since
    /// the last admit must still pass the byte bound.
    fn fits(&self, user: u64, bytes: usize, capacity: usize) -> bool {
        let held = self.window.get(user).map(|&(_, b)| b).unwrap_or(0);
        self.window_bytes - held + bytes <= capacity
    }

    /// Record an admission.
    fn admit(&mut self, user: u64, now: u64, bytes: usize, est_window: usize) {
        self.fp.push(est_window, bytes as f64);
        if let Some(&(_, old)) = self.window.get(user) {
            self.window_bytes -= old;
        }
        self.window.insert(user, (now, bytes));
        self.window_bytes += bytes;
        self.order.push_back((now, user));
    }

    /// An admit was cancelled before its production started: free the
    /// user's footprint reservation (its order slot becomes a tombstone).
    fn cancel(&mut self, user: u64) {
        if let Some((_, bytes)) = self.window.remove(user) {
            self.window_bytes -= bytes;
        }
    }
}

/// Per-special-instance trigger state.
pub struct Trigger {
    cfg: TriggerConfig,
    limits: AdmissionLimits,
    bucket: TokenBucket,
    /// Live caches currently attributed to this instance (feedback).
    live: usize,
    adapt: AdaptiveState,
    estimator: Estimator,
    stats: TriggerStats,
}

/// Counters exported to metrics.  Adaptation fields snapshot the
/// controller: the effective-headroom trajectory (milli-units, min/max
/// over the run), the windowed footprint estimate vs the provisioned
/// static bound, and the occupancy-aware live-cache limit in effect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TriggerStats {
    pub assessed: u64,
    pub not_at_risk: u64,
    pub admitted: u64,
    pub rate_limited: u64,
    pub footprint_limited: u64,
    /// `release()` calls (paired with admits by the coordinator).
    pub released: u64,
    /// Releases that arrived with no admit outstanding — an accounting
    /// bug upstream; Eq. 2 would silently over-admit if these were
    /// absorbed, so they are counted (and debug-asserted) instead.
    pub spurious_release: u64,
    /// Decisions served by the adaptive controller.
    pub adapted: u64,
    /// Effective risk-headroom trajectory, in milli-units (static mode
    /// pins both ends to the configured constant).
    pub headroom_milli_min: u64,
    pub headroom_milli_max: u64,
    /// Latest windowed distinct-user footprint estimate (bytes).
    pub footprint_est_bytes: u64,
    /// The provisioned static bound it replaces (l_max · kv_p99).
    pub footprint_static_bytes: u64,
    /// Occupancy-aware live-cache bound in effect at the last decide.
    pub l_max_effective: u64,
}

impl Default for TriggerStats {
    fn default() -> TriggerStats {
        TriggerStats {
            assessed: 0,
            not_at_risk: 0,
            admitted: 0,
            rate_limited: 0,
            footprint_limited: 0,
            released: 0,
            spurious_release: 0,
            adapted: 0,
            // Sentinel so merge() can take min across instances.
            headroom_milli_min: u64::MAX,
            headroom_milli_max: 0,
            footprint_est_bytes: 0,
            footprint_static_bytes: 0,
            l_max_effective: 0,
        }
    }
}

impl TriggerStats {
    /// Accumulate another instance's counters (cluster-wide reporting).
    /// Counters sum; the headroom trajectory takes the envelope; the
    /// footprint/bound snapshots sum (cluster-wide capacity in caches).
    pub fn merge(&mut self, b: TriggerStats) {
        self.assessed += b.assessed;
        self.not_at_risk += b.not_at_risk;
        self.admitted += b.admitted;
        self.rate_limited += b.rate_limited;
        self.footprint_limited += b.footprint_limited;
        self.released += b.released;
        self.spurious_release += b.spurious_release;
        self.adapted += b.adapted;
        self.headroom_milli_min = self.headroom_milli_min.min(b.headroom_milli_min);
        self.headroom_milli_max = self.headroom_milli_max.max(b.headroom_milli_max);
        self.footprint_est_bytes += b.footprint_est_bytes;
        self.footprint_static_bytes += b.footprint_static_bytes;
        self.l_max_effective += b.l_max_effective;
    }
}

/// Samples before the windowed estimators drive the operating point;
/// until then the (per-scenario) initial operating point holds.
const ADAPT_WARMUP: usize = 8;
/// Pressure mapping: estimated rank-stage P99 at `PRESSURE_LO · budget`
/// is fully relaxed, at `PRESSURE_HI · budget` fully tightened.
const PRESSURE_LO: f64 = 0.5;
const PRESSURE_HI: f64 = 1.0;

impl Trigger {
    pub fn new(cfg: TriggerConfig, estimator: Estimator) -> Trigger {
        let limits = cfg.limits();
        // Burst sized to the slot count: a short spike can fill the slots,
        // sustained rate is capped at q_admit_max.
        let burst = cfg.m_slots.max(1) as f64;
        let stats = TriggerStats {
            footprint_static_bytes: limits.l_max as u64 * cfg.kv_p99_bytes as u64,
            l_max_effective: limits.l_max as u64,
            ..TriggerStats::default()
        };
        Trigger {
            bucket: TokenBucket::new(limits.q_admit_max, burst),
            limits,
            cfg,
            live: 0,
            adapt: AdaptiveState::default(),
            estimator,
            stats,
        }
    }

    pub fn limits(&self) -> AdmissionLimits {
        self.limits
    }

    pub fn config(&self) -> &TriggerConfig {
        &self.cfg
    }

    pub fn stats(&self) -> TriggerStats {
        self.stats
    }

    pub fn live(&self) -> usize {
        self.live
    }

    /// The r1·HBM slice the footprint bound protects (Eq. 2's right-hand
    /// side) — the same budget the static `l_max` divides by `kv_p99`,
    /// so segment-cache partitions never shift admission decisions.
    fn psi_capacity(&self) -> usize {
        (self.cfg.r1 * self.cfg.hbm_bytes as f64) as usize
    }

    /// The adaptive operating point `(effective headroom, rate
    /// multiplier)` — the scenario's initial point until the window warms
    /// up, then the windowed-pressure control law.
    fn operating_point(&self) -> (f64, f64) {
        let adm = &self.cfg.admission;
        // Small --adapt-window values cap the ring below ADAPT_WARMUP;
        // clamp so the control law still engages once the window fills.
        if self.adapt.est.len() < ADAPT_WARMUP.min(adm.est_window) {
            let h = adm.headroom_init.unwrap_or(self.cfg.headroom);
            let m = adm.rate_mult_init.unwrap_or(adm.rate_mult_max);
            return (
                h.clamp(adm.headroom_min, adm.headroom_max),
                m.clamp(adm.rate_mult_min, adm.rate_mult_max),
            );
        }
        let p99 = self.adapt.est.p99().expect("warm window");
        let pressure = p99 / self.cfg.rank_p99_budget_us.max(1.0);
        let t = ((pressure - PRESSURE_LO) / (PRESSURE_HI - PRESSURE_LO)).clamp(0.0, 1.0);
        // Near-SLO: tighten the risk margin (more traffic classified
        // at-risk and relayed) and open the admitted rate toward the
        // Eq. 3 compute cap; idle budget: relax both.
        let h = adm.headroom_max - t * (adm.headroom_max - adm.headroom_min);
        let m = adm.rate_mult_min + t * (adm.rate_mult_max - adm.rate_mult_min);
        (h, m)
    }

    /// Occupancy-aware live-cache bound: capacity over the *observed*
    /// footprint P99 (the provisioned `kv_p99` until admissions exist).
    pub fn effective_l_max(&self) -> usize {
        match self.cfg.admission.mode {
            AdmissionMode::Static => self.limits.l_max,
            AdmissionMode::Adaptive => {
                let fp = self.adapt.fp.p99().unwrap_or(self.cfg.kv_p99_bytes as f64);
                (self.psi_capacity() as f64 / fp.max(1.0)).floor() as usize
            }
        }
    }

    /// The windowed distinct-user footprint estimate (bytes).
    pub fn footprint_estimate(&self) -> usize {
        self.adapt.window_bytes
    }

    fn note_headroom(&mut self, headroom: f64) {
        let milli = (headroom * 1000.0).round() as u64;
        self.stats.headroom_milli_min = self.stats.headroom_milli_min.min(milli);
        self.stats.headroom_milli_max = self.stats.headroom_milli_max.max(milli);
    }

    /// Metadata risk test + admission control.  `kv_bytes` is the ψ
    /// footprint this request would produce — the observed-footprint
    /// feedback the adaptive bound replaces `kv_p99_bytes` with (the
    /// static path ignores it).
    pub fn decide(&mut self, now_us: u64, meta: &BehaviorMeta, kv_bytes: usize) -> Decision {
        self.stats.assessed += 1;
        let est_full_us = (self.estimator)(meta);
        if self.cfg.admission.mode == AdmissionMode::Static {
            // The original Eqs. 1–3 flow, decision-for-decision.
            self.note_headroom(self.cfg.headroom);
            if est_full_us <= self.cfg.headroom * self.cfg.rank_p99_budget_us {
                self.stats.not_at_risk += 1;
                return Decision::NotAtRisk;
            }
            if self.live >= self.limits.l_max {
                self.stats.footprint_limited += 1;
                return Decision::FootprintLimited;
            }
            if !self.bucket.try_take(now_us) {
                self.stats.rate_limited += 1;
                return Decision::RateLimited;
            }
            self.live += 1;
            self.stats.admitted += 1;
            return Decision::Admit;
        }
        // Closed loop (all signals decision-synchronous; see module doc).
        // The effective estimate charges the configured microbatch
        // window to admission: an admitted request cannot start ranking
        // before the batch former releases it, so an aggressive window
        // consumes real headroom the controller would otherwise
        // attribute to compute.  The fault plan's worst-case retry
        // budget is charged the same way — backoff is latency the
        // request may pay before the ladder resolves it.
        let est_eff =
            est_full_us + (self.cfg.batch_window_us + self.cfg.retry_budget_us) as f64;
        self.stats.adapted += 1;
        self.adapt.est.push(self.cfg.admission.est_window, est_eff);
        let (headroom, rate_mult) = self.operating_point();
        self.note_headroom(headroom);
        let decision = 'adapt: {
            if est_eff <= headroom * self.cfg.rank_p99_budget_us {
                self.stats.not_at_risk += 1;
                break 'adapt Decision::NotAtRisk;
            }
            // The window may be lengthened (more conservative) but never
            // shortened below T_life: a reservation that expired while
            // its cache was still live would void the Eq. 2 bound.
            let window_us = self
                .cfg
                .admission
                .window_us
                .unwrap_or(self.cfg.t_life_us)
                .max(self.cfg.t_life_us);
            self.adapt.prune(now_us, window_us);
            if !self.adapt.fits(meta.user, kv_bytes, self.psi_capacity()) {
                self.stats.footprint_limited += 1;
                break 'adapt Decision::FootprintLimited;
            }
            self.bucket.set_rate(self.cfg.q_m * self.cfg.m_slots as f64 * rate_mult);
            if !self.bucket.try_take(now_us) {
                self.stats.rate_limited += 1;
                break 'adapt Decision::RateLimited;
            }
            self.adapt.admit(meta.user, now_us, kv_bytes, self.cfg.admission.est_window);
            self.live += 1;
            self.stats.admitted += 1;
            Decision::Admit
        };
        // One snapshot per decide, after the decision resolved (the
        // occupancy-aware bound costs a ring sort — hot-path budget is
        // tracked by bench_admission.rs).
        self.stats.footprint_est_bytes = self.adapt.window_bytes as u64;
        self.stats.l_max_effective = self.effective_l_max() as u64;
        decision
    }

    /// Feedback: a cache left the live set (consumed, expired or lost).
    /// Every release must pair with an admit — a stray release would
    /// silently under-count `live` and over-admit against Eq. 2, so it
    /// is counted (and debug-asserted) instead of absorbed.
    pub fn release(&mut self) {
        self.stats.released += 1;
        if self.live == 0 {
            self.stats.spurious_release += 1;
            debug_assert!(false, "trigger: release without a matching admit");
            return;
        }
        self.live -= 1;
    }

    /// An admit was cancelled before its production started (HBM
    /// overcommit at signal time): free the slot and, in adaptive mode,
    /// the user's windowed footprint reservation.
    pub fn cancel_admit(&mut self, user: u64) {
        self.adapt.cancel(user);
        self.release();
    }

    /// Whether a request with this metadata is at risk (no admission).
    /// Uses the static margin; callers wanting the adaptive margin go
    /// through [`Trigger::decide`], which also feeds the estimators.
    pub fn at_risk(&self, meta: &BehaviorMeta) -> bool {
        (self.estimator)(meta) > self.cfg.headroom * self.cfg.rank_p99_budget_us
    }
}

/// `relaygr plan` — print the derived Eqs. 1–3 limits, defaulting to the
/// paper's §3.2 sanity-check numbers.  With `--admission adaptive` the
/// closed-loop operating bands and the per-scenario initial operating
/// points are printed too (`--kv-obs-gb` sets the observed per-user ψ
/// footprint the occupancy-aware bound would see).
pub fn plan_cli(args: &Args) -> Result<()> {
    let d = TriggerConfig::paper_example();
    let cfg = TriggerConfig {
        rank_p99_budget_us: args.get_f64("budget-ms", d.rank_p99_budget_us / 1e3)? * 1e3,
        headroom: args.get_f64("headroom", d.headroom)?,
        t_life_us: (args.get_f64("t-life-ms", d.t_life_us as f64 / 1e3)? * 1e3) as u64,
        kv_p99_bytes: (args.get_f64("kv-gb", d.kv_p99_bytes as f64 / 1e9)? * 1e9) as usize,
        hbm_bytes: (args.get_f64("hbm-gb", d.hbm_bytes as f64 / 1e9)? * 1e9) as usize,
        r1: args.get_f64("r1", d.r1)?,
        q_m: args.get_f64("qm", d.q_m)?,
        m_slots: args.get_usize("slots", d.m_slots)?,
        r2: args.get_f64("r2", d.r2)?,
        n_instances: args.get_usize("instances", d.n_instances)?,
        batch_window_us: d.batch_window_us,
        retry_budget_us: d.retry_budget_us,
        admission: AdmissionConfig::from_args(args, &d.admission)?,
    };
    let lim = cfg.limits();
    println!("sequence-aware trigger: admission plan (Eqs. 1-3)");
    println!("  HBM reserved for live caches (r1*HBM) : {:>10.1} GB", cfg.r1 * cfg.hbm_bytes as f64 / 1e9);
    println!("  kv_p99 per admitted user              : {:>10.3} GB", cfg.kv_p99_bytes as f64 / 1e9);
    println!("  L_max live caches / special instance  : {:>10}", lim.l_max);
    println!("  T_life lifecycle window               : {:>10.0} ms", cfg.t_life_us as f64 / 1e3);
    println!("  Q_admit cap (survivability, Eq.1-2)   : {:>10.1} q/s", lim.l_max as f64 / (cfg.t_life_us as f64 / 1e6));
    println!("  Q_admit cap (compute, Eq.3)           : {:>10.1} q/s", cfg.q_m * cfg.m_slots as f64);
    println!("  Q_admit effective per special instance: {:>10.1} q/s", lim.q_admit_max);
    println!("  special instances (r2*N)              : {:>10}", lim.specials);
    println!("  Q_max system-wide admitted traffic    : {:>10.1} q/s", lim.q_max_system);
    if cfg.admission.is_adaptive() {
        use crate::workload::ScenarioKind;
        let adm = &cfg.admission;
        let capacity = cfg.r1 * cfg.hbm_bytes as f64;
        let kv_obs =
            args.get_f64("kv-obs-gb", cfg.kv_p99_bytes as f64 / 1e9)? * 1e9;
        println!("\nclosed-loop adaptive admission (observed-load operating bands)");
        println!(
            "  risk headroom band                    : [{:.2} .. {:.2}] x budget",
            adm.headroom_min, adm.headroom_max
        );
        println!(
            "  admitted-rate band                    : [{:.2} .. {:.2}] x Qm*M = [{:.1} .. {:.1}] q/s",
            adm.rate_mult_min,
            adm.rate_mult_max,
            adm.rate_mult_min * cfg.q_m * cfg.m_slots as f64,
            adm.rate_mult_max * cfg.q_m * cfg.m_slots as f64,
        );
        println!(
            "  L_max at observed kv ({:>6.3} GB)      : {:>10} (static bound: {})",
            kv_obs / 1e9,
            (capacity / kv_obs.max(1.0)).floor() as usize,
            lim.l_max,
        );
        println!("  per-scenario initial operating points (headroom / rate-mult):");
        for name in ScenarioKind::NAMES {
            let kind = ScenarioKind::parse(name).expect("built-in scenario");
            let p = kind.admission_profile();
            println!(
                "    {name:<10} headroom {:.2}   rate-mult {:.2}",
                p.headroom_init, p.rate_mult_init
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic ψ footprint used where the test doesn't care.
    const KV: usize = 32 << 20;

    fn meta(prefix_len: usize) -> BehaviorMeta {
        BehaviorMeta { user: 1, prefix_len, dim: 256 }
    }

    fn user_meta(user: u64) -> BehaviorMeta {
        BehaviorMeta { user, prefix_len: 4096, dim: 256 }
    }

    /// Estimator: 20 µs per token (2K tokens → 41 ms, at risk vs 40 ms line).
    fn linear_estimator() -> Estimator {
        Box::new(|m: &BehaviorMeta| m.prefix_len as f64 * 20.0)
    }

    #[test]
    fn paper_sanity_check_numbers() {
        // §3.2: kv=0.1GB, HBM=32GB, r1=0.5 → L ≤ 160; Qm=30, M=5 → 150 QPS;
        // N=100, r2=0.1 → pool cap 1500 QPS.
        let lim = TriggerConfig::paper_example().limits();
        assert_eq!(lim.l_max, 160);
        assert!((lim.q_admit_max - 150.0).abs() < 1e-9, "{}", lim.q_admit_max);
        assert_eq!(lim.specials, 10);
        assert!((lim.q_max_system - 1500.0).abs() < 1e-9);
    }

    #[test]
    fn survivability_binds_when_t_life_large() {
        // With a 2 s lifecycle, Eq. 1 gives 160/2 = 80 QPS < 150 QPS compute.
        let mut cfg = TriggerConfig::paper_example();
        cfg.t_life_us = 2_000_000;
        let lim = cfg.limits();
        assert!((lim.q_admit_max - 80.0).abs() < 1e-9, "{}", lim.q_admit_max);
    }

    #[test]
    fn short_sequences_not_at_risk() {
        let mut t = Trigger::new(TriggerConfig::paper_example(), linear_estimator());
        assert_eq!(t.decide(0, &meta(512), KV), Decision::NotAtRisk);
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::Admit);
        let s = t.stats();
        assert_eq!((s.not_at_risk, s.admitted), (1, 1));
    }

    #[test]
    fn rate_limit_enforced_and_refills() {
        let mut cfg = TriggerConfig::paper_example();
        cfg.m_slots = 2; // burst 2, compute cap 60 q/s
        let mut t = Trigger::new(cfg, linear_estimator());
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::Admit);
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::Admit);
        t.release();
        t.release(); // footprint freed; rate still empty
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::RateLimited);
        // 60 q/s → one token every ~16.7 ms.
        assert_eq!(t.decide(20_000, &meta(4096), KV), Decision::Admit);
    }

    #[test]
    fn footprint_limit_uses_feedback() {
        let mut cfg = TriggerConfig::paper_example();
        cfg.kv_p99_bytes = 8_000_000_000; // 8 GB → L_max = 2
        cfg.q_m = 1e9; // rate never binds
        let mut t = Trigger::new(cfg, linear_estimator());
        assert_eq!(t.limits().l_max, 2);
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::Admit);
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::Admit);
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::FootprintLimited);
        t.release();
        assert_eq!(t.decide(1_000_000, &meta(4096), KV), Decision::Admit);
        assert_eq!(t.live(), 2);
    }

    #[test]
    fn token_bucket_sustained_rate() {
        let mut b = TokenBucket::new(100.0, 1.0); // 100/s, burst 1
        let mut granted = 0;
        for ms in 0..1000u64 {
            if b.try_take(ms * 1000) {
                granted += 1;
            }
        }
        assert!((95..=106).contains(&granted), "granted {granted}");
    }

    /// Satellite: a late-arriving earlier event must neither refund nor
    /// double-charge tokens — sim and serve deliver events in different
    /// orders, so the bucket's high-water clock must be monotone.
    #[test]
    fn token_bucket_out_of_order_timestamps() {
        let mut b = TokenBucket::new(1000.0, 1.0); // 1 token/ms, burst 1
        assert!(b.try_take(10_000), "burst token");
        // Earlier timestamp: dt saturates to 0 — no refund...
        assert!(!b.try_take(2_000));
        // ...and the high-water mark stays at 10 ms, so the next in-order
        // events refill from 10 ms, not from 2 ms (no double charge of
        // the elapsed window either way).
        assert!(!b.try_take(10_500), "only 0.5 tokens accrued since 10 ms");
        assert!(b.try_take(11_000), "exactly 1 token accrued since 10 ms");
        // Out-of-order events while empty keep the clock pinned.
        assert!(!b.try_take(3_000));
        assert!(!b.try_take(11_400));
        assert!(b.try_take(12_000));
    }

    #[test]
    fn token_bucket_set_rate_applies_to_next_refill() {
        let mut b = TokenBucket::new(100.0, 1.0);
        assert!(b.try_take(0));
        // 10× the rate: one token now takes 1 ms instead of 10 ms.
        b.set_rate(1000.0);
        assert!((b.rate_per_s() - 1000.0).abs() < 1e-9);
        assert!(!b.try_take(500));
        assert!(b.try_take(1_000));
    }

    /// Satellite: releases pair with admits exactly — `live` equals
    /// `admitted − released` under paired usage, and a stray release is
    /// surfaced as `spurious_release` instead of silently under-counting
    /// the Eq. 2 feedback.  The event mix covers the fault plane's new
    /// failure-path orderings: *retry-then-cancel* (an admit whose
    /// production retried, then got overcommit-cancelled — retries are
    /// priced, not slotted, so the cancel is the one and only release)
    /// and *crash-mid-rank* (the instance dies after admit; the wipe's
    /// release must still pair exactly once, never once per retry).
    #[test]
    fn prop_live_equals_admitted_minus_released() {
        crate::util::prop::check("trigger-release-accounting", 100, |rng| {
            let mut cfg = TriggerConfig::paper_example();
            cfg.q_m = 1e9; // rate never binds: exercise the slot ledger
            if rng.bernoulli(0.5) {
                cfg.admission = AdmissionConfig::adaptive();
            }
            // Retry pricing must not perturb the slot ledger either way.
            if rng.bernoulli(0.5) {
                cfg.retry_budget_us = 2_800;
            }
            let mut t = Trigger::new(cfg, Box::new(|_| 1e9));
            // Users with an admit outstanding, so cancels/releases pair.
            let mut open: Vec<u64> = Vec::new();
            let mut now = 0u64;
            for user in 0..300u64 {
                now += rng.range(0, 20_000) as u64;
                match rng.range(0, 10) {
                    0..=4 => {
                        if t.decide(now, &user_meta(user), KV) == Decision::Admit {
                            open.push(user);
                        }
                    }
                    5..=6 => {
                        // Completion or crash-mid-rank wipe: both paths
                        // release exactly once, whatever retries the
                        // production suffered before dying.
                        if open.pop().is_some() {
                            t.release();
                        }
                    }
                    _ => {
                        // Retry-then-cancel: the admit is cancelled at
                        // signal time after its (priced) retry window —
                        // one cancel, one release, footprint freed.
                        if !open.is_empty() {
                            let i = rng.range(0, open.len());
                            let u = open.swap_remove(i);
                            t.cancel_admit(u);
                        }
                    }
                }
                let s = t.stats();
                if s.spurious_release != 0 {
                    return Err("paired usage produced a spurious release".into());
                }
                if s.admitted - s.released != t.live() as u64 {
                    return Err(format!(
                        "live {} != admitted {} - released {}",
                        t.live(),
                        s.admitted,
                        s.released
                    ));
                }
                if t.live() != open.len() {
                    return Err(format!(
                        "live {} != outstanding admits {}",
                        t.live(),
                        open.len()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn spurious_release_is_counted() {
        let mut t = Trigger::new(TriggerConfig::paper_example(), linear_estimator());
        // The debug assertion fires in debug builds; the counter must
        // record the stray release either way.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.release()));
        assert_eq!(caught.is_err(), cfg!(debug_assertions));
        let s = t.stats();
        assert_eq!((s.released, s.spurious_release), (1, 1));
        assert_eq!(t.live(), 0);
        // A paired admit/release afterwards is clean.
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::Admit);
        t.release();
        assert_eq!(t.stats().spurious_release, 1);
        assert_eq!(t.live(), 0);
    }

    fn adaptive_cfg() -> TriggerConfig {
        let mut cfg = TriggerConfig::paper_example();
        cfg.admission = AdmissionConfig::adaptive();
        cfg
    }

    /// Tentpole: the observed-footprint window replaces the provisioned
    /// `kv_p99_bytes` — distinct users admit until their *actual* bytes
    /// fill the r1·HBM slice, and a hot user re-admits for free.
    #[test]
    fn adaptive_footprint_tracks_observed_bytes() {
        let mut cfg = adaptive_cfg();
        cfg.hbm_bytes = 1 << 30;
        cfg.r1 = 1.0;
        // Provisioned worst case says zero caches fit — the collapsed
        // static bound of a misprovisioned fleet.
        cfg.kv_p99_bytes = 2 << 30;
        cfg.q_m = 1e9;
        assert_eq!(cfg.limits().l_max, 0);
        let mut t = Trigger::new(cfg, Box::new(|_| 1e9));
        // Observed ψ is 256 MiB: exactly 4 distinct users fit.
        let kv = 256 << 20;
        for user in 0..4u64 {
            assert_eq!(t.decide(user, &user_meta(user), kv), Decision::Admit, "user {user}");
        }
        assert_eq!(t.decide(4, &user_meta(4), kv), Decision::FootprintLimited);
        // A user already inside the window re-admits without new bytes.
        assert_eq!(t.decide(5, &user_meta(2), kv), Decision::Admit);
        assert_eq!(t.footprint_estimate(), 4 * kv);
        assert_eq!(t.effective_l_max(), 4, "capacity / observed-footprint P99");
        let s = t.stats();
        assert_eq!(s.footprint_est_bytes, 4 * kv as u64);
        assert_eq!(s.footprint_static_bytes, 0, "collapsed static bound");
        // The window expires with T_life: a new user admits again.
        let later = t.config().t_life_us * 2;
        assert_eq!(t.decide(later, &user_meta(9), kv), Decision::Admit);
    }

    /// Satellite: the configured microbatch window is decision-
    /// synchronous latency, so the adaptive controller charges it to the
    /// admission estimate.  A request estimated just inside the risk
    /// boundary flips from NotAtRisk to Admit once the window is folded
    /// in — and the static path (paper Eqs. 1–3) must ignore the window
    /// entirely.
    #[test]
    fn adaptive_estimate_charges_batch_window() {
        // Initial operating point: headroom 0.8 × 50 ms budget = 40 ms
        // boundary.  Estimator pinned at 39 ms, 1 ms under the line.
        let boundary_est: fn() -> Estimator = || Box::new(|_: &BehaviorMeta| 39_000.0);
        let mut cfg = adaptive_cfg();
        cfg.q_m = 1e9; // rate never binds — isolate the risk comparison
        let mut t = Trigger::new(cfg.clone(), boundary_est());
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::NotAtRisk);
        // A 20 ms window pushes the effective estimate to 59 ms > 40 ms:
        // the same request is now at risk and admitted to the relay path.
        cfg.batch_window_us = 20_000;
        let mut t = Trigger::new(cfg.clone(), boundary_est());
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::Admit);
        assert_eq!(t.stats().not_at_risk, 0);
        // Static admission has no batching term: same window, same
        // estimator, still NotAtRisk (the paper's flow is untouched).
        cfg.admission = AdmissionConfig::default();
        let mut t = Trigger::new(cfg, boundary_est());
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::NotAtRisk);
    }

    /// The fault plan's worst-case retry budget is priced exactly like
    /// the batch window: it moves the adaptive risk classification and
    /// stacks with the window, while the static path ignores it.
    #[test]
    fn adaptive_estimate_charges_retry_budget() {
        let boundary_est: fn() -> Estimator = || Box::new(|_: &BehaviorMeta| 39_000.0);
        let mut cfg = adaptive_cfg();
        cfg.q_m = 1e9;
        let mut t = Trigger::new(cfg.clone(), boundary_est());
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::NotAtRisk);
        // A 2.8 ms retry budget (400 µs · (2³−1)) pushes 39 ms over the
        // 40 ms line: the request is now at risk and relayed.
        cfg.retry_budget_us = 2_800;
        let mut t = Trigger::new(cfg.clone(), boundary_est());
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::Admit);
        // Budget and window stack — both are latency the request pays.
        cfg.batch_window_us = 20_000;
        let mut t = Trigger::new(cfg.clone(), boundary_est());
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::Admit);
        // Static admission keeps the paper's Eqs. 1–3 untouched.
        cfg.admission = AdmissionConfig::default();
        let mut t = Trigger::new(cfg, boundary_est());
        assert_eq!(t.decide(0, &meta(4096), KV), Decision::NotAtRisk);
    }

    /// The risk margin tightens toward `headroom_min` when the windowed
    /// latency estimate crowds the budget, and relaxes to `headroom_max`
    /// when the budget is idle.
    #[test]
    fn adaptive_headroom_follows_pressure() {
        // Budget 50 ms; estimator returns 30 µs/token.
        let est: Estimator = Box::new(|m: &BehaviorMeta| m.prefix_len as f64 * 30.0);
        let mut cfg = adaptive_cfg();
        cfg.q_m = 1e9;
        let mut t = Trigger::new(cfg, est);
        // Warm the window with near-budget traffic (1600 tokens → 48 ms,
        // pressure ≈ 0.96 → margin ≈ headroom_min).
        for i in 0..16u64 {
            t.decide(i, &meta(1600), KV);
        }
        // 900 tokens → 27 ms: above headroom_min·budget (25 ms) ⇒ still
        // classified at-risk under the tightened margin.
        assert_eq!(t.decide(20, &meta(900), KV), Decision::Admit);
        let tight = t.stats();
        assert!(tight.headroom_milli_min <= 550, "tightened: {tight:?}");
        // Fresh trigger warmed with idle traffic (400 tokens → 12 ms,
        // pressure ≈ 0.24 → margin ≈ headroom_max): the same 27 ms
        // request is now comfortably inside the relaxed margin.
        let est2: Estimator = Box::new(|m: &BehaviorMeta| m.prefix_len as f64 * 30.0);
        let mut relaxed = Trigger::new(adaptive_cfg(), est2);
        for i in 0..16u64 {
            relaxed.decide(i, &meta(400), KV);
        }
        assert_eq!(relaxed.decide(20, &meta(900), KV), Decision::NotAtRisk);
        assert!(relaxed.stats().headroom_milli_max >= 900, "{:?}", relaxed.stats());
    }

    /// Under pressure the admitted rate opens toward the Eq. 3 compute
    /// cap instead of the (often far smaller) Eq. 1 survivability proxy.
    #[test]
    fn adaptive_rate_opens_to_compute_cap_under_pressure() {
        let mut cfg = adaptive_cfg();
        // Static rate would be min(l_max/T_life, Qm·M) = 160/0.3s ≈ 533…
        // shrink T_life's proxy hard: one admit per 10 s.  (The byte
        // window floors at T_life, but 40 × 32 MB sits far below the
        // 16 GB slice, so the footprint bound stays slack here.)
        cfg.t_life_us = 1_600_000_000;
        cfg.m_slots = 2; // burst 2
        assert!(cfg.limits().q_admit_max < 1.0);
        let mut t = Trigger::new(cfg, Box::new(|_| 1e9)); // always at risk
        // Pressure is maximal (est ≫ budget) ⇒ rate = Qm·M = 60/s.
        let mut admitted = 0;
        for i in 0..40u64 {
            // 40 distinct users over 1 s.
            if t.decide(i * 25_000, &user_meta(i), KV) == Decision::Admit {
                admitted += 1;
            }
        }
        // Static would admit ≈ burst (2); the opened bucket sustains
        // ~60/s → nearly every spaced request.
        assert!(admitted >= 30, "admitted {admitted} of 40");
    }

    /// A re-admitting user whose footprint *grew* (longer prefix since
    /// the last admit) still answers to the byte bound — only unchanged
    /// footprints re-admit for free.
    #[test]
    fn adaptive_readmission_charges_footprint_growth() {
        let mut cfg = adaptive_cfg();
        cfg.hbm_bytes = 1 << 30;
        cfg.r1 = 1.0;
        cfg.q_m = 1e9;
        let mut t = Trigger::new(cfg, Box::new(|_| 1e9));
        assert_eq!(t.decide(0, &user_meta(1), 300 << 20), Decision::Admit);
        assert_eq!(t.decide(1, &user_meta(2), 600 << 20), Decision::Admit);
        // User 1 returns with a footprint that would overflow the slice:
        // 600 (held by 2) + 700 > 1024 MB even after releasing its old
        // 300 MB reservation.
        assert_eq!(t.decide(2, &user_meta(1), 700 << 20), Decision::FootprintLimited);
        // Same-size re-admission stays free.
        assert_eq!(t.decide(3, &user_meta(1), 300 << 20), Decision::Admit);
        // Growth that still fits is charged and admitted.
        assert_eq!(t.decide(4, &user_meta(1), 400 << 20), Decision::Admit);
        assert_eq!(t.footprint_estimate(), (600 + 400) << 20);
    }

    /// An `--adapt-window` below the warmup constant must not pin the
    /// controller at its initial operating point forever — the control
    /// law engages once the (small) window fills.
    #[test]
    fn adaptive_small_window_still_engages_control_law() {
        let mut cfg = adaptive_cfg();
        cfg.admission.est_window = 2;
        cfg.q_m = 1e9;
        let mut t = Trigger::new(cfg, Box::new(|_| 1e9)); // est ≫ budget
        for i in 0..4u64 {
            t.decide(i, &user_meta(1), KV);
        }
        let s = t.stats();
        assert_eq!(
            s.headroom_milli_min, 500,
            "pressure must tighten headroom to headroom_min: {s:?}"
        );
    }

    #[test]
    fn adaptive_cancel_frees_footprint_reservation() {
        let mut cfg = adaptive_cfg();
        cfg.hbm_bytes = 1 << 30;
        cfg.r1 = 1.0;
        cfg.q_m = 1e9;
        let kv = 512 << 20;
        let mut t = Trigger::new(cfg, Box::new(|_| 1e9));
        assert_eq!(t.decide(0, &user_meta(1), kv), Decision::Admit);
        assert_eq!(t.decide(1, &user_meta(2), kv), Decision::Admit);
        assert_eq!(t.decide(2, &user_meta(3), kv), Decision::FootprintLimited);
        // User 2's production was cancelled (HBM overcommit): both the
        // slot and the windowed bytes come back.
        t.cancel_admit(2);
        assert_eq!(t.live(), 1);
        assert_eq!(t.footprint_estimate(), kv);
        assert_eq!(t.decide(3, &user_meta(3), kv), Decision::Admit);
        assert_eq!(t.stats().spurious_release, 0);
    }

    #[test]
    fn admission_config_from_args_parses_and_validates() {
        let args = |v: &[&str]| {
            Args::parse(std::iter::once("prog".to_string()).chain(v.iter().map(|s| s.to_string())))
                .unwrap()
        };
        let d = AdmissionConfig::default();
        assert_eq!(AdmissionConfig::from_args(&args(&[]), &d).unwrap(), d);
        let a = AdmissionConfig::from_args(
            &args(&[
                "plan", "--admission", "adaptive", "--headroom-min", "0.6", "--rate-mult-max",
                "0.9",
            ]),
            &d,
        )
        .unwrap();
        assert!(a.is_adaptive());
        assert!((a.headroom_min - 0.6).abs() < 1e-12);
        assert!((a.rate_mult_max - 0.9).abs() < 1e-12);
        let seeded = {
            let mut c = a.clone();
            c.seed_operating_point(0.7, 0.5);
            c
        };
        assert_eq!(seeded.headroom_init, Some(0.7));
        // Explicit inits win over the scenario seed.
        let explicit = AdmissionConfig::from_args(
            &args(&["plan", "--admission", "adaptive", "--headroom-init", "0.66"]),
            &d,
        )
        .unwrap();
        let mut c = explicit;
        c.seed_operating_point(0.7, 0.5);
        assert_eq!(c.headroom_init, Some(0.66));
        // Invalid shapes rejected — including explicit operating points
        // outside their bands (no silent clamping of explicit flags).
        for bad in [
            vec!["p", "--admission", "sometimes"],
            vec!["p", "--headroom-min", "0.9", "--headroom-max", "0.6"],
            vec!["p", "--rate-mult-min", "0"],
            vec!["p", "--adapt-window", "1"],
            vec!["p", "--headroom-init", "0.3"],
            vec!["p", "--rate-mult-init", "1.5"],
        ] {
            assert!(AdmissionConfig::from_args(&args(&bad), &d).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn prop_admitted_never_exceeds_limits() {
        crate::util::prop::check("trigger-bounds", 100, |rng| {
            let mut cfg = TriggerConfig::paper_example();
            cfg.kv_p99_bytes = (1 + rng.range(0, 20)) * 1_000_000_000;
            cfg.q_m = rng.uniform(1.0, 50.0);
            cfg.m_slots = 1 + rng.range(0, 8);
            let limits = cfg.limits();
            let mut t = Trigger::new(cfg, Box::new(|_| 1e9)); // always at risk
            let mut now = 0u64;
            let mut admitted_in_window = 0u64;
            for _ in 0..300 {
                now += rng.range(0, 20_000) as u64;
                match t.decide(now, &meta(4096), KV) {
                    Decision::Admit => admitted_in_window += 1,
                    _ => {}
                }
                if t.live() > limits.l_max {
                    return Err(format!("live {} > L_max {}", t.live(), limits.l_max));
                }
                if rng.bernoulli(0.3) {
                    t.release();
                }
            }
            // Sustained admission ≤ q_admit_max * elapsed + burst slack.
            let cap = limits.q_admit_max * (now as f64 / 1e6) + t.config().m_slots as f64 + 1.0;
            if (admitted_in_window as f64) > cap {
                return Err(format!("admitted {admitted_in_window} > cap {cap:.1}"));
            }
            Ok(())
        });
    }
}
