//! The tiered ψ cache hierarchy (§3.4 generalised): the HBM lifecycle
//! window (level 0) over any number of capacity-bounded lower tiers
//! (level 1 = server-local DRAM, deeper levels free to add), composed
//! into the flow the memory-aware expander hand-rolled for exactly two
//! levels:
//!
//! * **N-level lookup** — HBM first, then lower tiers top-down; a
//!   lower-tier hit triggers one rate-limited promotion (reload) into
//!   HBM.
//! * **Per-user single-flight** — at most one cache-affecting action per
//!   user in flight; concurrent requests join the in-flight promotion.
//! * **Pseudo-pre-inference** — every ranking request is fronted by an
//!   idempotent pseudo step performing the same checks as real
//!   pre-inference, so out-of-order arrivals (pre-infer delayed behind
//!   ranking) cause at most one reload per user per burst.
//! * **Bounded promotion concurrency** — reloads above the cap queue
//!   rather than flooding PCIe.
//! * **Demotion (spill)** — a consumed ψ demotes into level 1; entries a
//!   tier evicts cascade one level down, and only entries evicted from
//!   the last tier leave the hierarchy.
//!
//! Eviction inside each lower tier is policy-driven
//! ([`EvictPolicy`](crate::relay::tier::EvictPolicy)); the no-remote-fetch
//! invariant (I1) is preserved because every tier is server-local.
//!
//! Like [`HbmCache`], the hierarchy is payload-generic and clock-agnostic
//! (callers pass `now_us` and perform the actual H2D/D2H), so the
//! simulator and the live engine share it.

use std::collections::VecDeque;

use crate::util::sharded::ShardedMap;

use crate::relay::hbm::{EntryState, HbmCache, Micros};
use crate::relay::tier::{PolicyTier, TierConfig, TierStats};

/// What the pseudo-pre-infer step decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PseudoAction {
    /// ψ is in HBM (Ready or Consumed-but-resident): proceed directly.
    HbmHit,
    /// ψ is still being produced in HBM: wait for production to finish.
    WaitProducing,
    /// Lower-tier hit; this caller starts the one promotion (caller
    /// performs the H2D and calls [`CacheHierarchy::complete_reload`]).
    StartReload { bytes: usize },
    /// Lower-tier hit but a promotion for this user is already in flight
    /// (or queued): join it, do not issue another transfer.
    JoinReload,
    /// Lower-tier hit but the promotion-concurrency cap is reached: the
    /// reload is queued; the caller waits for its
    /// [`CacheHierarchy::pop_queued_reload`] turn.
    QueuedReload,
    /// Not cached anywhere: fall back (full inference or real pre-infer).
    Miss,
}

/// Flow + per-tier counters exported to metrics.  The flow counters keep
/// the historical names (`reloads_*`, `spills`, `dram_*`) — a reload is
/// a promotion into HBM, a spill is a demotion out of it, and
/// `dram_evictions` counts entries evicted out of the *last* tier, i.e.
/// out of the hierarchy entirely.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HierarchyStats {
    pub lookups: u64,
    pub hbm_hits: u64,
    /// Hits in any lower tier (historically: the DRAM tier).
    pub dram_hits: u64,
    pub misses: u64,
    pub reloads_started: u64,
    pub reloads_joined: u64,
    pub reloads_queued: u64,
    pub spills: u64,
    pub spill_rejected: u64,
    /// Entries evicted out of the last tier (left the hierarchy).
    pub dram_evictions: u64,
    /// Cascade moves from one lower tier into the next.
    pub demotions: u64,
    /// Per-lower-tier counters, top-down (level 1 first).
    pub tiers: Vec<TierStats>,
}

impl HierarchyStats {
    /// Accumulate another instance's counters (cluster-wide reporting);
    /// tier vectors merge index-wise.
    pub fn merge(&mut self, b: HierarchyStats) {
        self.lookups += b.lookups;
        self.hbm_hits += b.hbm_hits;
        self.dram_hits += b.dram_hits;
        self.misses += b.misses;
        self.reloads_started += b.reloads_started;
        self.reloads_joined += b.reloads_joined;
        self.reloads_queued += b.reloads_queued;
        self.spills += b.spills;
        self.spill_rejected += b.spill_rejected;
        self.dram_evictions += b.dram_evictions;
        self.demotions += b.demotions;
        if self.tiers.len() < b.tiers.len() {
            self.tiers.resize(b.tiers.len(), TierStats::default());
        }
        for (a, t) in self.tiers.iter_mut().zip(b.tiers) {
            a.merge(t);
        }
    }
}

/// Result of [`CacheHierarchy::complete_reload`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReloadDone {
    /// Ranking requests that joined this reload instead of re-transferring.
    pub joiners: u32,
    /// Whether ψ was installed into HBM (false ⇒ HBM pressure; fall back).
    pub installed: bool,
    /// Next queued reload now permitted to start, if any.
    pub next: Option<u64>,
}

/// The tiered cache hierarchy: one HBM lifecycle window over N policy
/// tiers, plus the promotion/demotion flow state.
#[derive(Debug)]
pub struct CacheHierarchy<T> {
    hbm: HbmCache<T>,
    /// Lower tiers, top-down (level 1 = DRAM first).
    lower: Vec<PolicyTier<T>>,
    /// Users with a promotion in flight (single-flight) and join counts.
    /// Sharded by user-id hash (trace scale); every access is keyed.
    inflight: ShardedMap<u32>,
    /// Promotions waiting for a concurrency slot, FIFO.
    queued: VecDeque<u64>,
    active_reloads: usize,
    max_reload_concurrency: usize,
    stats: HierarchyStats,
}

impl<T: Clone> CacheHierarchy<T> {
    /// `hbm_bytes` is the r1·HBM slice (Eq. 2); `tiers` the lower levels
    /// top-down (empty = plain RelayGR without a compensation tier).
    pub fn new(hbm_bytes: usize, tiers: &[TierConfig], max_reload_concurrency: usize) -> Self {
        CacheHierarchy {
            hbm: HbmCache::new(hbm_bytes),
            lower: tiers.iter().map(|&c| PolicyTier::from_config(c)).collect(),
            inflight: ShardedMap::new(),
            queued: VecDeque::new(),
            active_reloads: 0,
            max_reload_concurrency: max_reload_concurrency.max(1),
            stats: HierarchyStats::default(),
        }
    }

    // ---- introspection -----------------------------------------------------

    /// The level-0 lifecycle window (raw produce/consume lifecycle ops).
    pub fn hbm(&self) -> &HbmCache<T> {
        &self.hbm
    }

    pub fn hbm_mut(&mut self) -> &mut HbmCache<T> {
        &mut self.hbm
    }

    /// Number of levels including HBM.
    pub fn levels(&self) -> usize {
        1 + self.lower.len()
    }

    /// Flow counters plus a per-lower-tier stats snapshot.
    pub fn stats(&self) -> HierarchyStats {
        let mut s = self.stats.clone();
        s.tiers = self.lower.iter().map(|t| t.stats()).collect();
        s
    }

    /// Bytes resident across all lower tiers.
    pub fn dram_used_bytes(&self) -> usize {
        self.lower.iter().map(|t| t.used_bytes()).sum()
    }

    /// Entries resident across all lower tiers.
    pub fn dram_len(&self) -> usize {
        self.lower.iter().map(|t| t.len()).sum()
    }

    pub fn active_reloads(&self) -> usize {
        self.active_reloads
    }

    pub fn inflight_for(&self, user: u64) -> bool {
        self.inflight.contains_key(user)
    }

    // ---- N-level lookup ----------------------------------------------------

    /// The pseudo-pre-infer step fronting every ranking request (and also
    /// used by real pre-infer signals to skip redundant recomputation).
    pub fn pseudo_pre_infer(&mut self, user: u64, now: Micros) -> PseudoAction {
        self.stats.lookups += 1;
        match self.hbm.probe(user, now) {
            Some(EntryState::Ready) | Some(EntryState::Consumed) => {
                self.stats.hbm_hits += 1;
                return PseudoAction::HbmHit;
            }
            Some(EntryState::Producing) => {
                self.stats.hbm_hits += 1;
                return PseudoAction::WaitProducing;
            }
            None => {}
        }
        // Single-flight: join any in-flight/queued promotion for this user.
        if let Some(joiners) = self.inflight.get_mut(user) {
            *joiners += 1;
            self.stats.reloads_joined += 1;
            return PseudoAction::JoinReload;
        }
        // Lower tiers, top-down; the first hit promotes.
        let mut found = None;
        for tier in &mut self.lower {
            if let Some((bytes, _)) = tier.get(user) {
                tier.record_promotion();
                found = Some(bytes);
                break;
            }
        }
        let Some(bytes) = found else {
            self.stats.misses += 1;
            return PseudoAction::Miss;
        };
        self.stats.dram_hits += 1;
        self.inflight.insert(user, 0);
        if self.active_reloads < self.max_reload_concurrency {
            self.active_reloads += 1;
            self.stats.reloads_started += 1;
            PseudoAction::StartReload { bytes }
        } else {
            self.queued.push_back(user);
            self.stats.reloads_queued += 1;
            PseudoAction::QueuedReload
        }
    }

    /// Read the payload backing a promotion the caller is about to
    /// perform (the H2D reads this host copy).  Searches tiers top-down
    /// *without* touching recency/frequency: the decision lookup already
    /// happened in [`CacheHierarchy::pseudo_pre_infer`], and only the
    /// live engine reads payloads — a mutating read here would make the
    /// engines' eviction state diverge.
    pub fn payload_below(&mut self, user: u64) -> Option<(usize, T)> {
        self.lower.iter().find_map(|t| t.peek(user))
    }

    // ---- promotion (reload) ------------------------------------------------

    /// The H2D finished: install ψ into HBM as Ready, release the
    /// single-flight guard, and return (a) how many waiters were joined to
    /// this reload and (b) the next queued user now allowed to start (the
    /// caller begins its transfer).  The lower-tier copy stays resident
    /// (promotion copies; the HBM window slides independently).
    pub fn complete_reload(
        &mut self,
        user: u64,
        payload: T,
        bytes: usize,
        now: Micros,
        t_life_us: Micros,
    ) -> ReloadDone {
        let (joiners, next) = self.finish_reload(user);
        let installed = self.hbm.insert_ready(user, bytes, payload, now, t_life_us).is_ok();
        ReloadDone { joiners, installed, next }
    }

    /// Release single-flight/concurrency bookkeeping for a finished
    /// promotion *without* touching HBM — used by the live engine, whose
    /// HBM window holds device buffers while lower tiers hold host copies.
    pub fn finish_reload(&mut self, user: u64) -> (u32, Option<u64>) {
        let joiners = self.inflight.remove(user).unwrap_or(0);
        self.active_reloads = self.active_reloads.saturating_sub(1);
        (joiners, self.pop_queued_reload())
    }

    /// Pull the next queued promotion if a concurrency slot is free.
    /// Returns the user whose transfer should start now.
    pub fn pop_queued_reload(&mut self) -> Option<u64> {
        if self.active_reloads >= self.max_reload_concurrency {
            return None;
        }
        let user = self.queued.pop_front()?;
        self.active_reloads += 1;
        self.stats.reloads_started += 1;
        Some(user)
    }

    /// A promotion failed (e.g. the payload was evicted from its tier
    /// mid-flight): release guards so waiters can fall back.
    pub fn abort_reload(&mut self, user: u64) -> Option<u64> {
        self.inflight.remove(user);
        self.active_reloads = self.active_reloads.saturating_sub(1);
        self.pop_queued_reload()
    }

    // ---- demotion (spill) --------------------------------------------------

    /// After ranking consumed ψ, demote it into level 1 for short-term
    /// reuse.  Victims a tier evicts to make room cascade one level down;
    /// entries evicted from the last tier leave the hierarchy.
    pub fn spill(&mut self, user: u64, bytes: usize, payload: T) -> bool {
        if self.lower.is_empty() {
            self.stats.spill_rejected += 1;
            return false;
        }
        // One copy per user below HBM: a stale copy left in a deeper
        // tier by an earlier cascade would shadow capacity there.
        for tier in &mut self.lower[1..] {
            tier.remove_entry(user);
        }
        match self.lower[0].insert_evicting(user, bytes, payload, false) {
            None => {
                self.stats.spill_rejected += 1;
                false
            }
            Some(evicted) => {
                self.stats.spills += 1;
                self.cascade(0, evicted);
                true
            }
        }
    }

    /// Push a tier's eviction victims one level down (recursively).
    fn cascade(&mut self, from: usize, evicted: Vec<(u64, usize, T)>) {
        for (user, bytes, payload) in evicted {
            let next = from + 1;
            if next >= self.lower.len() {
                self.stats.dram_evictions += 1;
                continue;
            }
            match self.lower[next].insert_evicting(user, bytes, payload, true) {
                Some(more) => {
                    self.stats.demotions += 1;
                    self.cascade(next, more);
                }
                // Too large for the deeper tier: it leaves the hierarchy.
                None => self.stats.dram_evictions += 1,
            }
        }
    }

    /// Remove and return every lower-tier host copy, in ascending user
    /// order (cell-drain migration needs an engine-independent order).
    /// The top-most tier's copy wins when a stale duplicate survives in
    /// a deeper tier — the same precedence as [`Self::payload_below`].
    /// Bypasses eviction stats: the copies leave by migration, not
    /// capacity pressure.
    pub fn drain_lower(&mut self) -> Vec<(u64, usize, T)> {
        let mut users: Vec<u64> = Vec::new();
        for t in &self.lower {
            users.extend(t.users_sorted());
        }
        users.sort_unstable();
        users.dedup();
        let mut out = Vec::with_capacity(users.len());
        for user in users {
            let mut taken = None;
            for t in &mut self.lower {
                if let Some(e) = t.remove_entry(user) {
                    taken.get_or_insert(e);
                }
            }
            if let Some((bytes, payload)) = taken {
                out.push((user, bytes, payload));
            }
        }
        out
    }

    /// Drop a user's lower-tier entries (e.g. behaviours were refreshed
    /// upstream and the cached prefix is stale).
    pub fn invalidate(&mut self, user: u64) -> bool {
        let mut removed = false;
        for tier in &mut self.lower {
            removed |= tier.remove_entry(user).is_some();
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::tier::EvictPolicy;

    const MB: usize = 1 << 20;

    fn tier(cap_mb: usize, policy: EvictPolicy) -> TierConfig {
        TierConfig::new(cap_mb * MB, policy)
    }

    fn setup(dram_mb: usize) -> CacheHierarchy<u32> {
        CacheHierarchy::new(64 * MB, &[tier(dram_mb, EvictPolicy::Lru)], 2)
    }

    #[test]
    fn two_level_lookup_order() {
        let mut h = setup(512);
        // Nothing anywhere → Miss.
        assert_eq!(h.pseudo_pre_infer(1, 0), PseudoAction::Miss);
        // In HBM → HbmHit (lower tiers not consulted).
        h.hbm_mut().insert_ready(1, MB, 7, 0, 300_000).unwrap();
        assert_eq!(h.pseudo_pre_infer(1, 0), PseudoAction::HbmHit);
        // Only in DRAM → StartReload.
        h.spill(2, MB, 9);
        assert_eq!(h.pseudo_pre_infer(2, 0), PseudoAction::StartReload { bytes: MB });
        let s = h.stats();
        assert_eq!((s.misses, s.hbm_hits, s.dram_hits), (1, 1, 1));
        assert_eq!(s.tiers.len(), 1);
        assert_eq!(s.tiers[0].promotions, 1);
    }

    #[test]
    fn wait_for_producing_entry() {
        let mut h = setup(512);
        h.hbm_mut().begin_produce(1, MB, 0, 300_000).unwrap();
        assert_eq!(h.pseudo_pre_infer(1, 0), PseudoAction::WaitProducing);
    }

    #[test]
    fn single_flight_joins_burst() {
        // Out-of-order burst: three ranking requests for the same user
        // arrive before the (delayed) real pre-infer. Exactly one reload.
        let mut h = setup(512);
        h.spill(5, 2 * MB, 42);
        assert_eq!(h.pseudo_pre_infer(5, 0), PseudoAction::StartReload { bytes: 2 * MB });
        assert_eq!(h.pseudo_pre_infer(5, 0), PseudoAction::JoinReload);
        assert_eq!(h.pseudo_pre_infer(5, 0), PseudoAction::JoinReload);
        let done = h.complete_reload(5, 42, 2 * MB, 10, 300_000);
        assert_eq!(done.joiners, 2);
        assert!(done.installed);
        assert_eq!(done.next, None);
        // Everyone now hits HBM; at-most-once reload per burst.
        assert_eq!(h.pseudo_pre_infer(5, 0), PseudoAction::HbmHit);
        assert_eq!(h.stats().reloads_started, 1);
    }

    #[test]
    fn reload_concurrency_bounded_and_fifo() {
        let mut h = setup(512);
        for u in 1..=4u64 {
            h.spill(u, MB, u as u32);
        }
        assert!(matches!(h.pseudo_pre_infer(1, 0), PseudoAction::StartReload { .. }));
        assert!(matches!(h.pseudo_pre_infer(2, 0), PseudoAction::StartReload { .. }));
        // Cap = 2: further reloads queue.
        assert_eq!(h.pseudo_pre_infer(3, 0), PseudoAction::QueuedReload);
        assert_eq!(h.pseudo_pre_infer(4, 0), PseudoAction::QueuedReload);
        assert_eq!(h.active_reloads(), 2);
        // Completing one grants the slot to user 3 (FIFO).
        let done = h.complete_reload(1, 1, MB, 5, 300_000);
        assert_eq!(done.next, Some(3));
        assert_eq!(h.active_reloads(), 2);
        let done = h.complete_reload(2, 2, MB, 6, 300_000);
        assert_eq!(done.next, Some(4));
    }

    #[test]
    fn spill_lru_eviction() {
        let mut h: CacheHierarchy<u32> =
            CacheHierarchy::new(64 * MB, &[tier(3, EvictPolicy::Lru)], 1);
        h.spill(1, MB, 1);
        h.spill(2, MB, 2);
        h.spill(3, MB, 3);
        // Touch 1 so 2 becomes LRU, then overflow.
        assert!(matches!(h.pseudo_pre_infer(1, 0), PseudoAction::StartReload { .. }));
        h.complete_reload(1, 1, MB, 0, 300_000);
        h.spill(4, MB, 4);
        assert_eq!(h.dram_len(), 3);
        assert_eq!(h.stats().dram_evictions, 1);
        // 2 was evicted; 3 and 4 remain.
        assert!(h.payload_below(2).is_none());
        assert!(h.payload_below(3).is_some());
        assert!(h.payload_below(4).is_some());
    }

    #[test]
    fn no_lower_tiers_always_misses_and_rejects_spills() {
        let mut h: CacheHierarchy<u32> = CacheHierarchy::new(64 * MB, &[], 4);
        assert_eq!(h.levels(), 1);
        assert!(!h.spill(1, MB, 1));
        assert_eq!(h.pseudo_pre_infer(1, 0), PseudoAction::Miss);
        assert_eq!(h.stats().spill_rejected, 1);
    }

    #[test]
    fn abort_releases_slot() {
        let mut h: CacheHierarchy<u32> =
            CacheHierarchy::new(64 * MB, &[tier(512, EvictPolicy::Lru)], 1);
        h.spill(1, MB, 1);
        h.spill(2, MB, 2);
        assert!(matches!(h.pseudo_pre_infer(1, 0), PseudoAction::StartReload { .. }));
        assert_eq!(h.pseudo_pre_infer(2, 0), PseudoAction::QueuedReload);
        assert_eq!(h.abort_reload(1), Some(2));
        assert_eq!(h.active_reloads(), 1);
    }

    #[test]
    fn invalidate_removes_stale_prefix_from_every_tier() {
        let mut h: CacheHierarchy<u32> = CacheHierarchy::new(
            64 * MB,
            &[tier(4, EvictPolicy::Lru), tier(512, EvictPolicy::Lru)],
            2,
        );
        h.spill(9, MB, 1);
        assert!(h.invalidate(9));
        assert_eq!(h.pseudo_pre_infer(9, 0), PseudoAction::Miss);
        assert!(!h.invalidate(9));
    }

    #[test]
    fn eviction_cascades_down_the_stack() {
        // Level 1 holds 2 MB, level 2 holds 8 MB: spilling a third entry
        // demotes the level-1 victim down instead of dropping it.
        let mut h: CacheHierarchy<u32> = CacheHierarchy::new(
            64 * MB,
            &[tier(2, EvictPolicy::Lru), tier(8, EvictPolicy::Lru)],
            2,
        );
        h.spill(1, MB, 1);
        h.spill(2, MB, 2);
        h.spill(3, MB, 3); // evicts 1 from level 1 → demoted to level 2
        let s = h.stats();
        assert_eq!(s.demotions, 1);
        assert_eq!(s.dram_evictions, 0);
        assert_eq!(s.tiers[1].demotions_in, 1);
        // The demoted entry is still promotable (found top-down).
        assert_eq!(h.pseudo_pre_infer(1, 0), PseudoAction::StartReload { bytes: MB });
        assert_eq!(s.tiers[0].evictions, 1);
    }

    #[test]
    fn respill_removes_stale_deeper_copies() {
        // A cascaded-down entry that is later re-spilled must hold
        // exactly one copy below HBM — the deeper stale copy goes.
        let mut h: CacheHierarchy<u32> = CacheHierarchy::new(
            64 * MB,
            &[tier(2, EvictPolicy::Lru), tier(8, EvictPolicy::Lru)],
            2,
        );
        h.spill(1, MB, 1);
        h.spill(2, MB, 2);
        h.spill(3, MB, 3); // user 1 cascades to level 2
        assert_eq!(h.dram_len(), 3);
        // User 1 comes back (promotion copies; the tier-2 copy stays),
        // is consumed, and re-spills into level 1.
        assert!(matches!(h.pseudo_pre_infer(1, 0), PseudoAction::StartReload { .. }));
        h.complete_reload(1, 1, MB, 0, 300_000);
        h.hbm_mut().consume(1);
        h.spill(1, MB, 1);
        // Exactly one copy each of users 1, 2, 3 remains below HBM: 1
        // re-entered level 1 (its stale level-2 copy was dropped), and
        // the level-1 victim it displaced cascaded down.
        assert_eq!(h.dram_len(), 3, "no shadowed duplicate below HBM");
        assert_eq!(h.dram_used_bytes(), 3 * MB);
        let s = h.stats();
        assert_eq!(s.dram_evictions, 0, "nothing left the hierarchy");
    }

    #[test]
    fn payload_reads_do_not_perturb_eviction_order() {
        // payload_below backs the live engine's H2D reads; it must not
        // refresh recency, or the engines' eviction decisions diverge.
        let mut h: CacheHierarchy<u32> =
            CacheHierarchy::new(64 * MB, &[tier(3, EvictPolicy::Lru)], 2);
        h.spill(1, MB, 1);
        h.spill(2, MB, 2);
        h.spill(3, MB, 3);
        // Repeated payload reads of the LRU entry...
        for _ in 0..5 {
            assert!(h.payload_below(1).is_some());
        }
        // ...must leave it the victim.
        h.spill(4, MB, 4);
        assert!(h.payload_below(1).is_none(), "peek must not have saved the LRU entry");
        assert!(h.payload_below(2).is_some());
    }

    #[test]
    fn last_tier_eviction_leaves_the_hierarchy() {
        let mut h: CacheHierarchy<u32> = CacheHierarchy::new(
            64 * MB,
            &[tier(2, EvictPolicy::Lru), tier(2, EvictPolicy::Lru)],
            2,
        );
        for u in 1..=5u64 {
            h.spill(u, MB, u as u32);
        }
        // 5 spills through a 2+2 MB stack: one entry must have dropped out.
        let s = h.stats();
        assert_eq!(h.dram_len(), 4);
        assert!(s.dram_evictions >= 1, "stack overflow must leave the hierarchy");
        assert_eq!(s.spills, 5);
    }

    #[test]
    fn cost_aware_tier_protects_expensive_entries() {
        let mut h: CacheHierarchy<u32> =
            CacheHierarchy::new(64 * MB, &[tier(8, EvictPolicy::CostAware)], 2);
        h.spill(1, 4 * MB, 1);
        // Reuse 1 twice: its retention weight is freq 3 × 4 MB = 12.
        assert!(matches!(h.pseudo_pre_infer(1, 0), PseudoAction::StartReload { .. }));
        h.complete_reload(1, 1, 4 * MB, 0, 300_000);
        h.hbm_mut().consume(1);
        h.hbm_mut().evict(1);
        assert!(matches!(h.pseudo_pre_infer(1, 0), PseudoAction::StartReload { .. }));
        h.complete_reload(1, 1, 4 * MB, 1, 300_000);
        // Cold small entry: weight 1 × 1 MB = 1 → evicts first.
        h.spill(2, MB, 2);
        h.spill(3, 4 * MB, 3);
        assert!(h.payload_below(1).is_some(), "hot expensive ψ survives");
        assert!(h.payload_below(2).is_none(), "cold cheap ψ evicted first");
    }

    /// Property: random interleavings never issue concurrent reloads for
    /// one user, never exceed the concurrency cap, and each burst causes
    /// at most one transfer — with any eviction policy on the DRAM tier.
    #[test]
    fn prop_single_flight_and_bounded_concurrency() {
        crate::util::prop::check("hierarchy-single-flight", 150, |rng| {
            let cap = 1 + rng.range(0, 3);
            let policy = *rng.choice(&[
                EvictPolicy::Lru,
                EvictPolicy::Lfu,
                EvictPolicy::CostAware,
                EvictPolicy::Lifecycle,
            ]);
            let mut h: CacheHierarchy<u32> =
                CacheHierarchy::new(1 << 30, &[TierConfig::new(1 << 30, policy)], cap);
            let users: Vec<u64> = (0..6).collect();
            for &u in &users {
                h.spill(u, MB, u as u32);
            }
            let mut inflight: Vec<u64> = Vec::new();
            for step in 0..300 {
                let u = *rng.choice(&users);
                if rng.bernoulli(0.6) {
                    match h.pseudo_pre_infer(u, 0) {
                        PseudoAction::StartReload { .. } => {
                            if inflight.contains(&u) {
                                return Err(format!("step {step}: duplicate reload for {u}"));
                            }
                            inflight.push(u);
                        }
                        PseudoAction::QueuedReload => {}
                        _ => {}
                    }
                } else if let Some(pos) =
                    (!inflight.is_empty()).then(|| rng.range(0, inflight.len()))
                {
                    let u = inflight.remove(pos);
                    let done = h.complete_reload(u, 0, MB, step as u64, 1 << 40);
                    if let Some(next) = done.next {
                        if inflight.contains(&next) {
                            return Err("queued duplicate".into());
                        }
                        inflight.push(next);
                    }
                }
                if h.active_reloads() > cap {
                    return Err(format!("active {} > cap {cap}", h.active_reloads()));
                }
            }
            Ok(())
        });
    }

    /// Property: whatever interleaving of lookups, spills, invalidations
    /// and completions/aborts occurs, the promotion machinery never
    /// wedges — every inflight user can always be resolved, aborting a
    /// user whose backing entry vanished releases its slot to the queue,
    /// and the queue drains to empty.
    #[test]
    fn prop_reload_abort_releases_waiters() {
        crate::util::prop::check("hierarchy-abort-drains", 120, |rng| {
            let cap = 1 + rng.range(0, 2);
            let mut h: CacheHierarchy<u32> =
                CacheHierarchy::new(1 << 30, &[TierConfig::new(64 * MB, EvictPolicy::Lru)], cap);
            let users: Vec<u64> = (0..8).collect();
            let mut inflight: Vec<u64> = Vec::new();
            for step in 0..400 {
                let u = *rng.choice(&users);
                match rng.range(0, 5) {
                    0 => {
                        h.spill(u, MB, u as u32);
                    }
                    1 => {
                        if let PseudoAction::StartReload { .. } = h.pseudo_pre_infer(u, 0) {
                            inflight.push(u);
                        }
                    }
                    // The backing entry vanishes mid-flight (stale
                    // prefix / cascade eviction).
                    2 => {
                        h.invalidate(u);
                    }
                    // Driver grants a reload its turn: payload gone ⇒
                    // abort, which must pass the slot on.
                    _ => {
                        if let Some(pos) =
                            (!inflight.is_empty()).then(|| rng.range(0, inflight.len()))
                        {
                            let u = inflight.remove(pos);
                            let next = if h.payload_below(u).is_some() {
                                let done = h.complete_reload(u, 0, MB, step as u64, 1 << 40);
                                done.next
                            } else {
                                h.abort_reload(u)
                            };
                            if h.inflight_for(u) {
                                return Err(format!("step {step}: {u} stuck inflight"));
                            }
                            if let Some(n) = next {
                                inflight.push(n);
                            }
                        }
                    }
                }
                if h.active_reloads() > cap {
                    return Err(format!("step {step}: cap exceeded"));
                }
            }
            // Drain: resolving every remaining inflight/queued user must
            // leave no guards behind.
            while let Some(u) = inflight.pop() {
                let next = if h.payload_below(u).is_some() {
                    h.complete_reload(u, 0, MB, 0, 1 << 40).next
                } else {
                    h.abort_reload(u)
                };
                if let Some(n) = next {
                    inflight.push(n);
                }
            }
            if h.active_reloads() != 0 {
                return Err("drain left active reloads".into());
            }
            Ok(())
        });
    }
}
