//! Pipeline cascade model (§2.1, Fig. 2): retrieval → pre-processing →
//! fine-grained ranking under per-stage tail budgets, plus the per-request
//! lifecycle record the metrics layer aggregates.

use crate::util::rng::Rng;

/// Per-stage latency budgets of the production-mirror pipeline (§4.1):
/// pipeline P99 ≤ 135 ms, ranking ≈ 50 ms budget, stages of tens of ms.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Mean / P99 of the retrieval stage (candidate generation).
    pub retrieval_mean_us: f64,
    pub retrieval_p99_us: f64,
    /// Mean / P99 of pre-processing (coarse ranking + feature transform).
    pub preproc_mean_us: f64,
    pub preproc_p99_us: f64,
    /// Ranking-stage P99 budget (the binding constraint).
    pub rank_budget_us: f64,
    /// End-to-end pipeline SLO (P99).
    pub pipeline_slo_us: f64,
    /// Required SLO success rate (paper: ≥ 99.9%).
    pub required_success: f64,
    /// Lifecycle window T_life for cache survivability.
    pub t_life_us: u64,
    /// Latency of the trigger's metadata fetch + risk test (side path).
    pub trigger_us: f64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            retrieval_mean_us: 25_000.0,
            retrieval_p99_us: 40_000.0,
            preproc_mean_us: 25_000.0,
            preproc_p99_us: 45_000.0,
            rank_budget_us: 50_000.0,
            pipeline_slo_us: 135_000.0,
            required_success: 0.999,
            t_life_us: 300_000,
            trigger_us: 1_000.0,
        }
    }
}

/// Log-normal stage-latency sampler matched to (mean, P99).
///
/// For LN(μ, σ): mean = exp(μ + σ²/2), P99 = exp(μ + 2.326σ); solving the
/// pair gives σ from `ln(p99/mean) = 2.326σ − σ²/2` (positive root).
#[derive(Debug, Clone, Copy)]
pub struct StageSampler {
    mu: f64,
    sigma: f64,
}

impl StageSampler {
    pub fn from_mean_p99(mean_us: f64, p99_us: f64) -> StageSampler {
        assert!(mean_us > 0.0 && p99_us > mean_us, "need p99 > mean > 0");
        let z = 2.3263478740408408; // Φ⁻¹(0.99)
        let r = (p99_us / mean_us).ln();
        // σ² /2 − zσ + r = 0  →  σ = z − sqrt(z² − 2r)  (small root).
        let disc = z * z - 2.0 * r;
        let sigma = if disc > 0.0 { z - disc.sqrt() } else { z };
        let mu = mean_us.ln() - sigma * sigma / 2.0;
        StageSampler { mu, sigma }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        rng.lognormal(self.mu, self.sigma)
    }

    pub fn mean(&self) -> f64 {
        (self.mu + self.sigma * self.sigma / 2.0).exp()
    }

    pub fn p99(&self) -> f64 {
        (self.mu + 2.3263478740408408 * self.sigma).exp()
    }
}

/// How the ranking stage obtained ψ (or didn't).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Baseline / not admitted: full inline inference.
    FullInference,
    /// ψ consumed straight from HBM (relay race worked end-to-end).
    HbmHit,
    /// ψ promoted from a lower cache tier (DRAM hit).
    DramHit,
    /// Joined an in-flight reload started by an earlier request.
    JoinedReload,
    /// Admitted but the cache was unavailable at ranking time (evicted,
    /// affinity break, production too slow) — safe fallback to full.
    Fallback,
    /// Load-shedding rung of the degradation ladder: an unrecovered
    /// fault plus shed pressure — the request is answered degraded
    /// (coarse-rank order) instead of paying full inference.
    Shed,
}

/// Per-request lifecycle record (timestamps in µs since sim start).
#[derive(Debug, Clone)]
pub struct Lifecycle {
    pub request: u64,
    pub user: u64,
    pub prefix_len: usize,
    pub arrival_us: u64,
    pub retrieval_done_us: u64,
    pub preproc_done_us: u64,
    pub rank_start_us: u64,
    pub done_us: u64,
    /// Component latencies the paper's Fig. 11c/13b break down.
    pub pre_us: f64,
    pub load_us: f64,
    pub rank_us: f64,
    /// Wait on the ranking path for ψ production / reload.
    pub wait_us: f64,
    pub outcome: CacheOutcome,
    pub admitted: bool,
    pub instance: usize,
}

impl Lifecycle {
    pub fn e2e_us(&self) -> f64 {
        (self.done_us - self.arrival_us) as f64
    }

    /// Ranking-stage latency (what the tens-of-ms budget constrains).
    pub fn rank_stage_us(&self) -> f64 {
        (self.done_us - self.preproc_done_us) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_matches_targets() {
        let s = StageSampler::from_mean_p99(25_000.0, 40_000.0);
        assert!((s.mean() - 25_000.0).abs() < 1.0);
        assert!((s.p99() - 40_000.0).abs() < 1.0);
        // Empirical check.
        let mut rng = Rng::new(11);
        let mut h = crate::util::stats::Histogram::new();
        for _ in 0..100_000 {
            h.record(s.sample(&mut rng));
        }
        assert!((h.mean() - 25_000.0).abs() / 25_000.0 < 0.03, "mean {}", h.mean());
        assert!((h.p99() - 40_000.0).abs() / 40_000.0 < 0.08, "p99 {}", h.p99());
    }

    #[test]
    fn sampler_extreme_tail_ratio() {
        let s = StageSampler::from_mean_p99(10_000.0, 80_000.0);
        assert!(s.p99() / s.mean() > 4.0);
        let mut rng = Rng::new(12);
        for _ in 0..1000 {
            assert!(s.sample(&mut rng) > 0.0);
        }
    }

    #[test]
    fn default_budgets_partition_slo() {
        let c = PipelineConfig::default();
        assert!(c.retrieval_p99_us + c.preproc_p99_us + c.rank_budget_us <= c.pipeline_slo_us);
        assert!(c.t_life_us as f64 >= c.pipeline_slo_us * 2.0, "T_life covers pipeline tail");
    }

    #[test]
    fn lifecycle_latency_accessors() {
        let lc = Lifecycle {
            request: 1,
            user: 2,
            prefix_len: 2048,
            arrival_us: 100,
            retrieval_done_us: 30_100,
            preproc_done_us: 55_100,
            rank_start_us: 55_100,
            done_us: 75_100,
            pre_us: 35_000.0,
            load_us: 0.0,
            rank_us: 8_000.0,
            wait_us: 0.0,
            outcome: CacheOutcome::HbmHit,
            admitted: true,
            instance: 3,
        };
        assert_eq!(lc.e2e_us(), 75_000.0);
        assert_eq!(lc.rank_stage_us(), 20_000.0);
    }
}
