//! Generic cache-tier abstraction: every level of the ψ memory hierarchy
//! (HBM sliding window, server-local DRAM, and any future level — CXL,
//! SSD, a remote host pool behind a strict latency bound) presents the
//! same shape: a byte-bounded map from user → ψ with a pluggable
//! eviction policy and a shared [`TierStats`] counter block.
//!
//! Two implementations live in the crate today:
//!
//! * [`HbmCache`](crate::relay::hbm::HbmCache) — the level-0 lifecycle
//!   tier ([`EvictPolicy::Lifecycle`]): entries live for one request
//!   lifecycle T_life and the window slides past consumed/expired ones.
//! * [`PolicyTier`] — the capacity-bounded lower tier used for DRAM (and
//!   any deeper level), with LRU / LFU / cost-aware / FIFO eviction
//!   behind an O(log n) ordered victim index — the previous DRAM tier
//!   scanned all entries per eviction (O(n)), which melts the hot path
//!   once the tier holds tens of thousands of ψ.
//!
//! [`CacheHierarchy`](crate::relay::hierarchy::CacheHierarchy) composes
//! N tiers into the lookup → single-flight → bounded promotion →
//! demotion flow.  To add a new *policy*, add an [`EvictPolicy`] variant
//! and its arm in [`PolicyTier::order_key`]; to add a new *level*, push
//! another [`TierConfig`] onto the stack — no other code changes.

use std::collections::BTreeSet;

use crate::util::fxhash::FxHashMap;

pub use crate::relay::hbm::Micros;

/// Per-tier eviction policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Sliding lifecycle window (HBM semantics): oldest-inserted first;
    /// in a [`PolicyTier`] this degenerates to FIFO insertion order.
    Lifecycle,
    /// Least-recently-used.
    Lru,
    /// Least-frequently-used (ties broken by recency).
    Lfu,
    /// MTServe-style cost-aware: retention weight = reload cost (∝ ψ
    /// bytes, the H2D transfer this tier saves) × reuse probability
    /// (estimated by access frequency).  Small, rarely-reused entries
    /// evict first; large hot ψ — the expensive ones to lose — stay.
    CostAware,
}

impl EvictPolicy {
    pub const NAMES: [&'static str; 4] = ["lifecycle", "lru", "lfu", "cost"];

    pub fn parse(s: &str) -> Result<EvictPolicy, String> {
        match s {
            "lifecycle" | "fifo" => Ok(EvictPolicy::Lifecycle),
            "lru" => Ok(EvictPolicy::Lru),
            "lfu" => Ok(EvictPolicy::Lfu),
            "cost" | "cost-aware" | "costaware" => Ok(EvictPolicy::CostAware),
            other => Err(format!(
                "unknown eviction policy '{other}' (available: {})",
                EvictPolicy::NAMES.join(" | ")
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EvictPolicy::Lifecycle => "lifecycle",
            EvictPolicy::Lru => "lru",
            EvictPolicy::Lfu => "lfu",
            EvictPolicy::CostAware => "cost",
        }
    }
}

/// Static description of one tier in a hierarchy stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierConfig {
    pub capacity_bytes: usize,
    pub policy: EvictPolicy,
}

impl TierConfig {
    pub fn new(capacity_bytes: usize, policy: EvictPolicy) -> TierConfig {
        TierConfig { capacity_bytes, policy }
    }

    /// `<size><g|m|b>:<policy>` in the `--tier` grammar — the largest
    /// unit that divides the capacity exactly — so emitted configs
    /// round-trip through the parser for every expressible size.
    pub fn label(&self) -> String {
        let (gib, mib) = (1usize << 30, 1usize << 20);
        if self.capacity_bytes >= gib && self.capacity_bytes % gib == 0 {
            format!("{}g:{}", self.capacity_bytes >> 30, self.policy.label())
        } else if self.capacity_bytes >= mib && self.capacity_bytes % mib == 0 {
            format!("{}m:{}", self.capacity_bytes >> 20, self.policy.label())
        } else {
            format!("{}b:{}", self.capacity_bytes, self.policy.label())
        }
    }
}

/// Capacity policy for the (single) DRAM tier as selected by the serving
/// mode string (`relaygr` vs `relaygr+dram<N>g`).  Richer stacks are
/// configured with explicit [`TierConfig`] lists (`--tier`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DramPolicy {
    /// No DRAM tier (plain RelayGR, 0% DRAM hit).
    Disabled,
    /// Capacity-bounded tier (bytes); eviction policy is configured
    /// separately (`--dram-policy`, default LRU).
    Capacity(usize),
}

impl DramPolicy {
    /// The tier stack this mode-level policy induces.
    pub fn tier_stack(&self, policy: EvictPolicy) -> Vec<TierConfig> {
        match *self {
            DramPolicy::Disabled => Vec::new(),
            DramPolicy::Capacity(bytes) => vec![TierConfig::new(bytes, policy)],
        }
    }
}

/// The counter block every tier exports, whatever its policy.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    pub inserts: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub rejected: u64,
    /// Lookups that fed a promotion (reload) into the tier above.
    pub promotions: u64,
    /// Entries demoted into this tier from the tier above (cascade).
    pub demotions_in: u64,
}

impl TierStats {
    /// Accumulate another instance's counters (cluster-wide reporting).
    pub fn merge(&mut self, b: TierStats) {
        self.inserts += b.inserts;
        self.hits += b.hits;
        self.misses += b.misses;
        self.evictions += b.evictions;
        self.rejected += b.rejected;
        self.promotions += b.promotions;
        self.demotions_in += b.demotions_in;
    }
}

/// What every level of the ψ hierarchy can do.  `t_life_us` is the
/// lifecycle hint: the level-0 window enforces it as the entry deadline;
/// capacity tiers (which are not lifecycle-bounded) ignore it.
pub trait CacheTier<T> {
    fn policy(&self) -> EvictPolicy;
    fn capacity_bytes(&self) -> usize;
    fn used_bytes(&self) -> usize;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn contains(&self, user: u64) -> bool;
    /// Non-destructive lookup: refreshes recency/frequency and counts a
    /// hit or miss.  Returns the entry size and a payload clone.
    fn lookup(&mut self, user: u64, now: Micros) -> Option<(usize, T)>;
    /// Insert (replacing any previous entry), evicting per policy to
    /// fit.  Returns false if the entry cannot fit at all.
    fn insert(&mut self, user: u64, bytes: usize, payload: T, now: Micros, t_life_us: Micros)
        -> bool;
    /// Explicitly evict one entry; true if it existed.
    fn evict(&mut self, user: u64) -> bool;
    fn tier_stats(&self) -> TierStats;
}

/// Victim-ordering key: (retention weight, recency tick, user).  The
/// BTreeSet's smallest element is always the next victim, so eviction is
/// O(log n) instead of a full scan.  Ticks are unique per tier, making
/// victim selection deterministic across runs and engines.
type OrdKey = (u64, u64, u64);

#[derive(Debug)]
struct TierEntry<T> {
    bytes: usize,
    payload: T,
    /// Tick at insertion (FIFO order for [`EvictPolicy::Lifecycle`]).
    inserted: u64,
    /// Tick of the last touch (LRU order).
    last_used: u64,
    /// Access count since insertion (LFU / cost-aware reuse estimate).
    freq: u64,
    /// Current position in the victim index (must be removed before any
    /// field it derives from changes).
    key: OrdKey,
}

/// A capacity-bounded cache tier with pluggable eviction, used for every
/// level below the HBM window.
#[derive(Debug)]
pub struct PolicyTier<T> {
    policy: EvictPolicy,
    capacity: usize,
    used: usize,
    entries: FxHashMap<u64, TierEntry<T>>,
    /// Ordered victim index; smallest key evicts first.
    index: BTreeSet<OrdKey>,
    tick: u64,
    stats: TierStats,
}

impl<T> PolicyTier<T> {
    pub fn new(capacity_bytes: usize, policy: EvictPolicy) -> Self {
        PolicyTier {
            policy,
            capacity: capacity_bytes,
            used: 0,
            entries: FxHashMap::default(),
            index: BTreeSet::new(),
            tick: 0,
            stats: TierStats::default(),
        }
    }

    pub fn from_config(cfg: TierConfig) -> Self {
        PolicyTier::new(cfg.capacity_bytes, cfg.policy)
    }

    pub fn policy(&self) -> EvictPolicy {
        self.policy
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity
    }

    pub fn used_bytes(&self) -> usize {
        self.used
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, user: u64) -> bool {
        self.entries.contains_key(&user)
    }

    /// Resident users in ascending id order.  Callers that act on the
    /// result (e.g. drain migration) need an engine-independent order,
    /// so the hash map's iteration order must never leak out.
    pub fn users_sorted(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.entries.keys().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn stats(&self) -> TierStats {
        self.stats
    }

    /// The hierarchy notes that a hit in this tier started a promotion.
    pub(crate) fn record_promotion(&mut self) {
        self.stats.promotions += 1;
    }

    fn order_key(policy: EvictPolicy, e: &TierEntry<T>, user: u64) -> OrdKey {
        match policy {
            EvictPolicy::Lifecycle => (0, e.inserted, user),
            EvictPolicy::Lru => (0, e.last_used, user),
            EvictPolicy::Lfu => (e.freq, e.last_used, user),
            // Retention weight = reload cost (ψ MB) × reuse estimate
            // (access count); integer arithmetic keeps victim order
            // exactly reproducible across engines.
            EvictPolicy::CostAware => {
                (e.freq.saturating_mul(((e.bytes >> 20) as u64).max(1)), e.last_used, user)
            }
        }
    }

    fn reindex(&mut self, user: u64) {
        // Entry fields changed: refresh its victim-index position.
        let policy = self.policy;
        if let Some(e) = self.entries.get_mut(&user) {
            self.index.remove(&e.key);
            e.key = Self::order_key(policy, e, user);
            self.index.insert(e.key);
        }
    }

    /// Remove one entry, returning its size and payload.
    pub fn remove_entry(&mut self, user: u64) -> Option<(usize, T)> {
        let e = self.entries.remove(&user)?;
        self.index.remove(&e.key);
        self.used -= e.bytes;
        Some((e.bytes, e.payload))
    }

    /// Insert (replacing any previous entry), evicting per policy to fit.
    /// Returns the evicted victims — `(user, bytes, payload)` — so the
    /// hierarchy can demote them one level down, or `None` when the
    /// entry is larger than the whole tier (rejected).  `demoted` marks
    /// inserts that are themselves cascade demotions from the tier above.
    pub fn insert_evicting(
        &mut self,
        user: u64,
        bytes: usize,
        payload: T,
        demoted: bool,
    ) -> Option<Vec<(u64, usize, T)>> {
        if bytes > self.capacity {
            self.stats.rejected += 1;
            return None;
        }
        self.remove_entry(user);
        let mut evicted = Vec::new();
        while self.used + bytes > self.capacity {
            let &victim_key = self.index.first().expect("used > 0 implies a victim");
            let victim = victim_key.2;
            let (vbytes, vpayload) = self.remove_entry(victim).expect("indexed entry exists");
            self.stats.evictions += 1;
            evicted.push((victim, vbytes, vpayload));
        }
        self.tick += 1;
        let mut e = TierEntry {
            bytes,
            payload,
            inserted: self.tick,
            last_used: self.tick,
            freq: 1,
            key: (0, 0, 0),
        };
        e.key = Self::order_key(self.policy, &e, user);
        self.index.insert(e.key);
        self.entries.insert(user, e);
        self.used += bytes;
        self.stats.inserts += 1;
        if demoted {
            self.stats.demotions_in += 1;
        }
        Some(evicted)
    }

    /// Read an entry without touching recency/frequency or counters —
    /// for payload reads backing an already-decided promotion.  Decision
    /// lookups go through [`PolicyTier::get`] so both engines perturb
    /// eviction state identically.
    pub fn peek(&self, user: u64) -> Option<(usize, T)>
    where
        T: Clone,
    {
        self.entries.get(&user).map(|e| (e.bytes, e.payload.clone()))
    }

    /// Lookup with recency/frequency refresh and hit/miss accounting.
    pub fn get(&mut self, user: u64) -> Option<(usize, T)>
    where
        T: Clone,
    {
        self.tick += 1;
        let t = self.tick;
        if !self.entries.contains_key(&user) {
            self.stats.misses += 1;
            return None;
        }
        {
            let e = self.entries.get_mut(&user).expect("present");
            e.last_used = t;
            e.freq += 1;
        }
        self.reindex(user);
        self.stats.hits += 1;
        let e = &self.entries[&user];
        Some((e.bytes, e.payload.clone()))
    }
}

impl<T: Clone> CacheTier<T> for PolicyTier<T> {
    fn policy(&self) -> EvictPolicy {
        PolicyTier::policy(self)
    }

    fn capacity_bytes(&self) -> usize {
        PolicyTier::capacity_bytes(self)
    }

    fn used_bytes(&self) -> usize {
        PolicyTier::used_bytes(self)
    }

    fn len(&self) -> usize {
        PolicyTier::len(self)
    }

    fn contains(&self, user: u64) -> bool {
        PolicyTier::contains(self, user)
    }

    fn lookup(&mut self, user: u64, _now: Micros) -> Option<(usize, T)> {
        self.get(user)
    }

    fn insert(
        &mut self,
        user: u64,
        bytes: usize,
        payload: T,
        _now: Micros,
        _t_life_us: Micros,
    ) -> bool {
        self.insert_evicting(user, bytes, payload, false).is_some()
    }

    fn evict(&mut self, user: u64) -> bool {
        self.remove_entry(user).is_some()
    }

    fn tier_stats(&self) -> TierStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::hbm::HbmCache;

    const MB: usize = 1 << 20;

    fn tier(cap_mb: usize, policy: EvictPolicy) -> PolicyTier<u32> {
        PolicyTier::new(cap_mb * MB, policy)
    }

    #[test]
    fn policy_parsing_round_trips() {
        for name in EvictPolicy::NAMES {
            assert_eq!(EvictPolicy::parse(name).unwrap().label(), name);
        }
        assert_eq!(EvictPolicy::parse("cost-aware").unwrap(), EvictPolicy::CostAware);
        assert_eq!(EvictPolicy::parse("fifo").unwrap(), EvictPolicy::Lifecycle);
        assert!(EvictPolicy::parse("mru").is_err());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut t = tier(3, EvictPolicy::Lru);
        for u in 1..=3u64 {
            t.insert_evicting(u, MB, u as u32, false).unwrap();
        }
        t.get(1); // 2 is now LRU
        let evicted = t.insert_evicting(4, MB, 4, false).unwrap();
        assert_eq!(evicted.iter().map(|&(u, _, _)| u).collect::<Vec<_>>(), vec![2]);
        assert!(t.contains(1) && t.contains(3) && t.contains(4));
        assert_eq!(t.stats().evictions, 1);
    }

    #[test]
    fn lfu_evicts_least_frequently_used() {
        let mut t = tier(3, EvictPolicy::Lfu);
        for u in 1..=3u64 {
            t.insert_evicting(u, MB, u as u32, false).unwrap();
        }
        // 1 and 3 get extra touches; 2 stays at freq 1 (insert only).
        t.get(1);
        t.get(1);
        t.get(3);
        let evicted = t.insert_evicting(4, MB, 4, false).unwrap();
        assert_eq!(evicted.iter().map(|&(u, _, _)| u).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn lifecycle_is_fifo_regardless_of_touches() {
        let mut t = tier(3, EvictPolicy::Lifecycle);
        for u in 1..=3u64 {
            t.insert_evicting(u, MB, u as u32, false).unwrap();
        }
        t.get(1); // recency must NOT save the oldest insert
        let evicted = t.insert_evicting(4, MB, 4, false).unwrap();
        assert_eq!(evicted.iter().map(|&(u, _, _)| u).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn cost_aware_keeps_expensive_hot_entries() {
        let mut t = tier(8, EvictPolicy::CostAware);
        // Big, frequently reused ψ (expensive reload × likely reuse).
        t.insert_evicting(1, 4 * MB, 1, false).unwrap();
        t.get(1);
        t.get(1);
        // Small cold ψ: cheap to reload, never reused after insert.
        t.insert_evicting(2, MB, 2, false).unwrap();
        // Medium entry, one reuse.
        t.insert_evicting(3, 2 * MB, 3, false).unwrap();
        t.get(3);
        // Overflow: weight(1)=3*4=12, weight(2)=1*1=1, weight(3)=2*2=4.
        let evicted = t.insert_evicting(4, 3 * MB, 4, false).unwrap();
        assert_eq!(evicted.iter().map(|&(u, _, _)| u).collect::<Vec<_>>(), vec![2]);
        assert!(t.contains(1) && t.contains(3) && t.contains(4));
    }

    #[test]
    fn oversized_insert_rejected() {
        let mut t = tier(2, EvictPolicy::Lru);
        assert!(t.insert_evicting(1, 3 * MB, 1, false).is_none());
        assert_eq!(t.stats().rejected, 1);
        assert_eq!(t.used_bytes(), 0);
    }

    #[test]
    fn replacement_updates_byte_accounting() {
        let mut t = tier(8, EvictPolicy::Lru);
        t.insert_evicting(1, 2 * MB, 1, false).unwrap();
        t.insert_evicting(1, 5 * MB, 2, false).unwrap();
        assert_eq!(t.used_bytes(), 5 * MB);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(1).unwrap().1, 2);
    }

    #[test]
    fn demoted_inserts_counted() {
        let mut t = tier(4, EvictPolicy::Lru);
        t.insert_evicting(1, MB, 1, true).unwrap();
        t.insert_evicting(2, MB, 2, false).unwrap();
        let s = t.stats();
        assert_eq!((s.inserts, s.demotions_in), (2, 1));
    }

    /// Both tier implementations behave identically through the trait:
    /// insert → contains → lookup hit → evict → lookup miss.
    fn exercise_tier<C: CacheTier<u32>>(t: &mut C) {
        assert!(t.insert(7, MB, 42, 0, 1_000_000));
        assert!(t.contains(7));
        assert_eq!(t.lookup(7, 0), Some((MB, 42)));
        assert!(t.evict(7));
        assert!(!t.contains(7));
        assert_eq!(t.lookup(7, 0), None);
        let s = t.tier_stats();
        assert!(s.inserts >= 1 && s.hits >= 1 && s.misses >= 1);
    }

    #[test]
    fn trait_unifies_hbm_and_policy_tiers() {
        let mut hbm: HbmCache<u32> = HbmCache::new(64 * MB);
        exercise_tier(&mut hbm);
        assert_eq!(CacheTier::<u32>::policy(&hbm), EvictPolicy::Lifecycle);
        for p in [EvictPolicy::Lru, EvictPolicy::Lfu, EvictPolicy::CostAware] {
            let mut t = tier(64, p);
            exercise_tier(&mut t);
            assert_eq!(CacheTier::<u32>::policy(&t), p);
        }
    }

    /// Property: the ordered-index tier agrees with a naive min-scan
    /// reference model on every eviction decision, for every policy,
    /// under random operation sequences — the O(log n) index is a pure
    /// perf change.
    #[test]
    fn prop_index_matches_min_scan_reference() {
        #[derive(Clone)]
        struct RefEntry {
            bytes: usize,
            inserted: u64,
            last_used: u64,
            freq: u64,
        }
        crate::util::prop::check("tier-index-vs-scan", 120, |rng| {
            let policy = *rng.choice(&[
                EvictPolicy::Lifecycle,
                EvictPolicy::Lru,
                EvictPolicy::Lfu,
                EvictPolicy::CostAware,
            ]);
            let cap = (2 + rng.range(0, 14)) * MB;
            let mut t: PolicyTier<u32> = PolicyTier::new(cap, policy);
            let mut model: std::collections::BTreeMap<u64, RefEntry> =
                std::collections::BTreeMap::new();
            let mut used = 0usize;
            let mut tick = 0u64;
            let key = |e: &RefEntry, u: u64| match policy {
                EvictPolicy::Lifecycle => (0, e.inserted, u),
                EvictPolicy::Lru => (0, e.last_used, u),
                EvictPolicy::Lfu => (e.freq, e.last_used, u),
                EvictPolicy::CostAware => {
                    (e.freq.saturating_mul(((e.bytes >> 20) as u64).max(1)), e.last_used, u)
                }
            };
            for step in 0..300 {
                let user = rng.range_u64(10);
                if rng.bernoulli(0.5) {
                    let bytes = (1 + rng.range(0, 4)) * MB;
                    let real = t.insert_evicting(user, bytes, 0, false);
                    if bytes > cap {
                        if real.is_some() {
                            return Err(format!("step {step}: oversized insert accepted"));
                        }
                        continue;
                    }
                    // Mirror in the model: replace, then evict min-key.
                    if let Some(old) = model.remove(&user) {
                        used -= old.bytes;
                    }
                    let mut evicted_model = Vec::new();
                    while used + bytes > cap {
                        let victim = model
                            .iter()
                            .min_by_key(|(&u, e)| key(e, u))
                            .map(|(&u, _)| u)
                            .expect("model victim");
                        used -= model.remove(&victim).unwrap().bytes;
                        evicted_model.push(victim);
                    }
                    tick += 1;
                    model.insert(
                        user,
                        RefEntry { bytes, inserted: tick, last_used: tick, freq: 1 },
                    );
                    used += bytes;
                    let evicted_real: Vec<u64> =
                        real.unwrap().iter().map(|&(u, _, _)| u).collect();
                    if evicted_real != evicted_model {
                        return Err(format!(
                            "step {step} ({policy:?}): victims {evicted_real:?} vs model {evicted_model:?}"
                        ));
                    }
                } else {
                    let real = t.get(user).is_some();
                    tick += 1;
                    let modeled = if let Some(e) = model.get_mut(&user) {
                        e.last_used = tick;
                        e.freq += 1;
                        true
                    } else {
                        false
                    };
                    if real != modeled {
                        return Err(format!("step {step}: hit mismatch for {user}"));
                    }
                }
                if t.used_bytes() != used || t.len() != model.len() {
                    return Err(format!(
                        "step {step}: accounting drift ({} vs {used} bytes, {} vs {} entries)",
                        t.used_bytes(),
                        t.len(),
                        model.len()
                    ));
                }
                if t.used_bytes() > cap {
                    return Err("capacity exceeded".into());
                }
            }
            Ok(())
        });
    }
}
