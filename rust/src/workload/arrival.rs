//! Arrival-time processes for the workload scenarios.
//!
//! Open-loop traffic is a Poisson process; non-steady scenarios modulate
//! the instantaneous rate λ(t) and sample by *thinning* (Lewis &
//! Shedler): candidates arrive at the peak rate λ_max and are accepted
//! with probability λ(t)/λ_max, which is exact for any bounded rate
//! function.  Everything is deterministic given the caller's [`Rng`].

use crate::util::rng::Rng;

/// Homogeneous Poisson arrivals at a fixed rate (queries/s).
#[derive(Debug, Clone, Copy)]
pub struct Poisson {
    t_us: f64,
    rate_per_us: f64,
}

impl Poisson {
    pub fn new(qps: f64) -> Poisson {
        Poisson { t_us: 0.0, rate_per_us: qps / 1e6 }
    }

    /// Current process time (µs).
    pub fn time_us(&self) -> u64 {
        self.t_us as u64
    }

    /// Advance to the next arrival and return its time (µs).
    pub fn next(&mut self, rng: &mut Rng) -> u64 {
        self.t_us += rng.exponential(self.rate_per_us);
        self.t_us as u64
    }
}

/// Non-homogeneous Poisson arrivals with instantaneous rate `rate_at(t_us)`
/// (queries/s), bounded by `peak_qps`, sampled by thinning.
pub struct ModulatedPoisson<F: Fn(f64) -> f64> {
    t_us: f64,
    peak_qps: f64,
    rate_at: F,
}

impl<F: Fn(f64) -> f64> ModulatedPoisson<F> {
    /// `rate_at` takes the time in µs and returns the rate in queries/s;
    /// it must never exceed `peak_qps`.
    pub fn new(peak_qps: f64, rate_at: F) -> ModulatedPoisson<F> {
        assert!(peak_qps > 0.0, "peak rate must be positive");
        ModulatedPoisson { t_us: 0.0, peak_qps, rate_at }
    }

    /// Next accepted arrival before `duration_us`, or `None` when the
    /// process has run past the horizon.
    pub fn next(&mut self, rng: &mut Rng, duration_us: u64) -> Option<u64> {
        loop {
            self.t_us += rng.exponential(self.peak_qps / 1e6);
            if self.t_us as u64 >= duration_us {
                return None;
            }
            let rate = (self.rate_at)(self.t_us).clamp(0.0, self.peak_qps);
            if rng.f64() < rate / self.peak_qps {
                return Some(self.t_us as u64);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_matches() {
        let mut rng = Rng::new(1);
        let mut p = Poisson::new(500.0);
        let mut n = 0u64;
        while p.next(&mut rng) < 10_000_000 {
            n += 1;
        }
        // 500 q/s over 10 s → 5000 ± ~5σ.
        assert!((4650..=5350).contains(&n), "n = {n}");
    }

    #[test]
    fn thinning_recovers_constant_rate() {
        // A "modulated" process with a constant rate must match Poisson
        // statistics even when accepted at 1/3 of the candidate rate.
        let mut rng = Rng::new(2);
        let mut p = ModulatedPoisson::new(300.0, |_| 100.0);
        let mut n = 0u64;
        while p.next(&mut rng, 20_000_000).is_some() {
            n += 1;
        }
        assert!((1750..=2250).contains(&n), "n = {n}");
    }

    #[test]
    fn thinning_tracks_modulation() {
        // Rate 0 in the first half, 200 q/s in the second: all arrivals
        // must land in the second half.
        let mut rng = Rng::new(3);
        let mut p =
            ModulatedPoisson::new(200.0, |t| if t < 5_000_000.0 { 0.0 } else { 200.0 });
        let mut first = 0u64;
        let mut second = 0u64;
        while let Some(t) = p.next(&mut rng, 10_000_000) {
            if t < 5_000_000 {
                first += 1;
            } else {
                second += 1;
            }
        }
        assert_eq!(first, 0);
        assert!((800..=1200).contains(&second), "second = {second}");
    }
}
