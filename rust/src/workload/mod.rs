//! Synthetic production-mirror workload (§4.1), organised as a scenario
//! engine.
//!
//! The paper evaluates with real queries whose key statistics it reports:
//! *"most users have short histories and fewer than 6% have long
//! sequences exceeding 2K tokens"*, request lifecycles of a few hundred
//! milliseconds, rapid-refresh bursts from the same user (the DRAM-reuse
//! opportunity), and hundreds of QPS per instance.  The [`scenario`]
//! module turns those statistics into *named traffic shapes* behind one
//! [`Scenario`] trait — `steady` (the paper's workload, bit-identical to
//! the original generator for a fixed seed), `diurnal`, `burst` and
//! `coldstart` — over the [`arrival`] processes, all deterministic from
//! a seed and selectable via [`WorkloadConfig::scenario`] (`--scenario`
//! in the CLIs).
//!
//! Per-user sequence length is a *stable function of the user id* (a
//! user's behaviour history does not change between their requests within
//! a run), drawn from a truncated log-normal fitted so that
//! `P(len > long_threshold) ≈ long_frac`.

pub mod arrival;
pub mod scenario;
pub mod trace;

pub use scenario::{
    AdmissionProfile, ArrivalStream, Burst, CandidateProfile, Coldstart, Diurnal, Scenario,
    ScenarioKind, Steady,
};
pub use trace::ReplaySource;

use crate::relay::trigger::BehaviorMeta;
use crate::util::rng::Rng;

/// Workload shape parameters.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Offered load, queries/s (open loop).
    pub qps: f64,
    /// Trace duration in µs of simulated time.
    pub duration_us: u64,
    /// User population size.
    pub num_users: u64,
    /// Zipf exponent for user popularity (natural same-user repeats).
    pub zipf_s: f64,
    /// Target fraction of users with prefix > `long_threshold` (~0.06).
    pub long_frac: f64,
    /// The "over-long sequence" service threshold (paper: e.g. 2K/4K).
    pub long_threshold: usize,
    /// Length clamps (tokens).
    pub min_prefix: usize,
    pub max_prefix: usize,
    /// Probability a (long-sequence) request is followed by a rapid-refresh
    /// burst, and the burst shape.
    pub refresh_prob: f64,
    pub refresh_burst_max: usize,
    pub refresh_gap_us: (u64, u64),
    /// If set, every long user's prefix is exactly this length — the
    /// controlled-length microbench setup of the paper's sweeps
    /// (Figs. 11a, 13, 14).
    pub fixed_long_len: Option<usize>,
    /// Traffic shape (`--scenario steady|diurnal|burst|coldstart`).
    pub scenario: ScenarioKind,
    /// Candidate-set shape for ranking-side segment reuse: per-request
    /// candidates drawn Zipf(`cand_zipf_s`) from a `cand_catalog`-item
    /// catalog, overlapped per the scenario's [`CandidateProfile`].
    /// Derived lazily by [`candidate_set`] from a request-keyed RNG
    /// stream, so traces and ψ decisions are untouched when unused.
    pub cand_per_request: usize,
    pub cand_catalog: u64,
    /// Zipf exponent of candidate-item popularity (`--zipf`).
    pub cand_zipf_s: f64,
    pub seed: u64,
    /// When set, arrivals are replayed verbatim from a recorded binary
    /// trace ([`trace`]) instead of being generated; the other fields
    /// (restored from the trace header) still drive candidate sets,
    /// admission seeding and long/short classification.
    pub replay: Option<ReplaySource>,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            qps: 300.0,
            duration_us: 30_000_000,
            num_users: 200_000,
            zipf_s: 1.05,
            long_frac: 0.06,
            long_threshold: 2048,
            min_prefix: 64,
            max_prefix: 8192,
            refresh_prob: 0.3,
            refresh_burst_max: 3,
            refresh_gap_us: (400_000, 3_000_000),
            fixed_long_len: None,
            scenario: ScenarioKind::Steady,
            cand_per_request: 64,
            cand_catalog: 100_000,
            cand_zipf_s: 1.1,
            seed: 42,
            replay: None,
        }
    }
}

/// One generated request.  Compact by design: at 100M-request scale the
/// arrival heap and the simulator's event queue are full of copies of
/// this record, so id / user / prefix length are `u32` (the id budget is
/// guarded at config parse and re-checked at emission) and the whole
/// record packs into 24 bytes instead of 40.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GenRequest {
    pub arrival_us: u64,
    pub id: u32,
    pub user: u32,
    /// Long-term behaviour prefix length for this user (tokens).
    pub prefix_len: u32,
    /// True for rapid-refresh follow-ups of an earlier request.
    pub is_refresh: bool,
}

impl GenRequest {
    /// Request id widened to the metrics/coordinator `u64` key space.
    #[inline]
    pub fn rid(&self) -> u64 {
        self.id as u64
    }

    /// User id widened to the coordinator's `u64` key space (the
    /// coordinator itself stays 64-bit: production user ids need it).
    #[inline]
    pub fn uid(&self) -> u64 {
        self.user as u64
    }

    /// Prefix length as the `usize` the model/cost layers consume.
    #[inline]
    pub fn plen(&self) -> usize {
        self.prefix_len as usize
    }

    pub fn meta(&self, dim: usize) -> BehaviorMeta {
        BehaviorMeta { user: self.uid(), prefix_len: self.plen(), dim }
    }
}

/// Fit LN(μ, σ) so that P(len > threshold) = long_frac with median well
/// below the threshold (short-history mass).
fn lognormal_params(cfg: &WorkloadConfig) -> (f64, f64) {
    // Median at threshold/4 → μ = ln(threshold/4).
    let mu = (cfg.long_threshold as f64 / 4.0).ln();
    // P(X > T) = 1 - Φ((lnT - μ)/σ) = long_frac → (lnT - μ)/σ = z(1-frac).
    let z = inv_phi(1.0 - cfg.long_frac);
    let sigma = ((cfg.long_threshold as f64).ln() - mu) / z;
    (mu, sigma)
}

/// Inverse standard-normal CDF (Acklam's rational approximation).
fn inv_phi(p: f64) -> f64 {
    debug_assert!((0.0..1.0).contains(&p));
    const A: [f64; 6] = [
        -39.69683028665376,
        220.9460984245205,
        -275.9285104469687,
        138.3577518672690,
        -30.66479806614716,
        2.506628277459239,
    ];
    const B: [f64; 5] = [
        -54.47609879822406,
        161.5858368580409,
        -155.6989798598866,
        66.80131188771972,
        -13.28068155288572,
    ];
    const C: [f64; 6] = [
        -0.007784894002430293,
        -0.3223964580411365,
        -2.400758277161838,
        -2.549732539343734,
        4.374664141464968,
        2.938163982698783,
    ];
    const D: [f64; 4] = [
        0.007784695709041462,
        0.3224671290700398,
        2.445134137142996,
        3.754408661907416,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Deterministic per-user prefix length.
pub fn user_prefix_len(cfg: &WorkloadConfig, user: u64) -> usize {
    let (mu, sigma) = lognormal_params(cfg);
    let mut rng = Rng::new(cfg.seed ^ user.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x1e57);
    let len = rng.lognormal(mu, sigma);
    let len = (len as usize).clamp(cfg.min_prefix, cfg.max_prefix);
    match cfg.fixed_long_len {
        Some(fixed) if len > cfg.long_threshold => fixed,
        _ => len,
    }
}

/// Generate the configured scenario's arrival trace, sorted by arrival
/// time.  `ScenarioKind::Steady` reproduces the pre-scenario generator
/// bit-for-bit for a fixed seed.  With [`WorkloadConfig::replay`] set the
/// trace is read back from the recorded file instead.
pub fn generate(cfg: &WorkloadConfig) -> Vec<GenRequest> {
    match &cfg.replay {
        Some(_) => stream(cfg).collect(),
        None => cfg.scenario.as_scenario().generate(cfg),
    }
}

/// Stream the configured scenario's arrivals lazily, in the exact order
/// [`generate`] would materialize them (which is itself just a collect of
/// this stream).  The simulator consumes this instead of a trace vector,
/// so memory stays O(live refresh bursts) at million-user scale.  With
/// [`WorkloadConfig::replay`] set, arrivals come verbatim from the
/// recorded trace (O(1) memory: one buffered reader).
pub fn stream(cfg: &WorkloadConfig) -> ArrivalStream {
    match &cfg.replay {
        Some(src) => ArrivalStream::replay(cfg, src),
        None => cfg.scenario.as_scenario().stream(cfg),
    }
}

/// Deterministic per-request candidate set (order-preserving, deduped):
/// Zipf-skewed item popularity over the catalog with the scenario's
/// overlap profile mixed in — hot draws come from the catalog's
/// most-popular head, so concurrent requests share them.  Drawn from a
/// request-keyed RNG stream independent of the arrival generator, so
/// enabling candidates never perturbs the trace itself.
pub fn candidate_set(cfg: &WorkloadConfig, req: &GenRequest) -> Vec<u64> {
    let mut out = Vec::new();
    candidate_set_into(cfg, req, &mut out);
    out
}

/// [`candidate_set`] into a caller-owned buffer (cleared first), so the
/// per-request hot path reuses one allocation across the whole run.  The
/// linear-scan dedup is exact for the order-preserving first-occurrence
/// semantics and allocation-free; candidate sets are tens of items.
pub fn candidate_set_into(cfg: &WorkloadConfig, req: &GenRequest, out: &mut Vec<u64>) {
    out.clear();
    if cfg.cand_per_request == 0 {
        return;
    }
    let profile = cfg.scenario.candidate_profile();
    let catalog = cfg.cand_catalog.max(1);
    let hot = profile.hot_items.clamp(1, catalog);
    let mut rng = Rng::new(cfg.seed ^ req.rid().wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xCA9D);
    out.reserve(cfg.cand_per_request);
    for _ in 0..cfg.cand_per_request {
        let item = if rng.bernoulli(profile.hot_frac) {
            rng.zipf(hot, cfg.cand_zipf_s) - 1
        } else {
            rng.zipf(catalog, cfg.cand_zipf_s) - 1
        };
        if !out.contains(&item) {
            out.push(item);
        }
    }
}

/// Trace statistics (sanity + tests + EXPERIMENTS.md reporting).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceStats {
    pub requests: usize,
    pub distinct_users: usize,
    pub long_user_frac: f64,
    pub long_request_frac: f64,
    pub refresh_frac: f64,
    pub mean_prefix: f64,
    pub effective_qps: f64,
}

pub fn stats(cfg: &WorkloadConfig, trace: &[GenRequest]) -> TraceStats {
    use std::collections::HashSet;
    let mut users: HashSet<u32> = HashSet::new();
    let mut long_users: HashSet<u32> = HashSet::new();
    let (mut long_req, mut refresh, mut sum_prefix) = (0usize, 0usize, 0f64);
    for r in trace {
        users.insert(r.user);
        if r.plen() > cfg.long_threshold {
            long_users.insert(r.user);
            long_req += 1;
        }
        if r.is_refresh {
            refresh += 1;
        }
        sum_prefix += r.prefix_len as f64;
    }
    let n = trace.len().max(1);
    TraceStats {
        requests: trace.len(),
        distinct_users: users.len(),
        long_user_frac: long_users.len() as f64 / users.len().max(1) as f64,
        long_request_frac: long_req as f64 / n as f64,
        refresh_frac: refresh as f64 / n as f64,
        mean_prefix: sum_prefix / n as f64,
        effective_qps: trace.len() as f64 / (cfg.duration_us as f64 / 1e6),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inv_phi_known_values() {
        assert!((inv_phi(0.5)).abs() < 1e-6);
        assert!((inv_phi(0.975) - 1.959964).abs() < 1e-4);
        assert!((inv_phi(0.94) - 1.554774).abs() < 1e-4);
        assert!((inv_phi(0.01) + 2.326348).abs() < 1e-4);
    }

    #[test]
    fn user_lengths_deterministic_and_clamped() {
        let cfg = WorkloadConfig::default();
        for u in 0..200u64 {
            let a = user_prefix_len(&cfg, u);
            let b = user_prefix_len(&cfg, u);
            assert_eq!(a, b);
            assert!((cfg.min_prefix..=cfg.max_prefix).contains(&a));
        }
    }

    #[test]
    fn long_user_fraction_near_target() {
        let cfg = WorkloadConfig::default();
        let long = (0..50_000u64)
            .filter(|&u| user_prefix_len(&cfg, u) > cfg.long_threshold)
            .count();
        let frac = long as f64 / 50_000.0;
        assert!(
            (frac - cfg.long_frac).abs() < 0.015,
            "long-user fraction {frac:.3} vs target {}",
            cfg.long_frac
        );
    }

    #[test]
    fn trace_rate_and_ordering() {
        let cfg = WorkloadConfig { duration_us: 20_000_000, qps: 500.0, ..Default::default() };
        let trace = generate(&cfg);
        let s = stats(&cfg, &trace);
        // Refreshes add on top of the base Poisson rate.
        assert!(s.effective_qps > 450.0 && s.effective_qps < 700.0, "{s:?}");
        assert!(trace.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        // ids unique
        let mut ids: Vec<u32> = trace.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), trace.len());
    }

    #[test]
    fn refreshes_keep_user_and_length() {
        let cfg = WorkloadConfig { refresh_prob: 1.0, ..Default::default() };
        let trace = generate(&cfg);
        use std::collections::HashMap;
        let base: HashMap<u32, u32> =
            trace.iter().filter(|r| !r.is_refresh).map(|r| (r.user, r.prefix_len)).collect();
        for r in trace.iter().filter(|r| r.is_refresh) {
            assert_eq!(base.get(&r.user), Some(&r.prefix_len));
            assert!(r.plen() > cfg.long_threshold, "only long users burst");
        }
        let s = stats(&cfg, &trace);
        assert!(s.refresh_frac > 0.02, "refresh traffic present: {s:?}");
    }

    #[test]
    fn determinism_per_seed() {
        let cfg = WorkloadConfig { duration_us: 5_000_000, ..Default::default() };
        assert_eq!(generate(&cfg), generate(&cfg));
        let cfg2 = WorkloadConfig { seed: 43, ..cfg };
        assert_ne!(generate(&cfg), generate(&cfg2));
    }

    #[test]
    fn candidate_sets_deterministic_deduped_and_bounded() {
        let cfg = WorkloadConfig::default();
        let req = GenRequest { id: 9, arrival_us: 0, user: 4, prefix_len: 4096, is_refresh: false };
        let a = candidate_set(&cfg, &req);
        assert_eq!(a, candidate_set(&cfg, &req), "same request ⇒ same candidates");
        assert!(!a.is_empty() && a.len() <= cfg.cand_per_request);
        assert!(a.iter().all(|&i| i < cfg.cand_catalog));
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), a.len(), "candidates are distinct items");
        // Different requests draw different sets (independent streams).
        let req2 = GenRequest { id: 10, ..req };
        assert_ne!(a, candidate_set(&cfg, &req2));
        // Disabled candidate generation yields nothing.
        let off = WorkloadConfig { cand_per_request: 0, ..cfg };
        assert!(candidate_set(&off, &req).is_empty());
    }

    #[test]
    fn scenario_overlap_knobs_order_scenarios() {
        use std::collections::HashSet;
        // Mean pairwise candidate-set intersection must rank burst
        // (flash crowd on trending items) above steady above coldstart —
        // the per-scenario knobs the segment cache's win depends on.
        let mean_shared = |kind: &str| {
            let cfg = WorkloadConfig {
                scenario: ScenarioKind::parse(kind).unwrap(),
                ..Default::default()
            };
            let sets: Vec<HashSet<u64>> = (0..120u32)
                .map(|id| {
                    let req = GenRequest {
                        id,
                        arrival_us: id as u64,
                        user: id,
                        prefix_len: 4096,
                        is_refresh: false,
                    };
                    candidate_set(&cfg, &req).into_iter().collect()
                })
                .collect();
            let shared: usize = sets
                .windows(2)
                .map(|w| w[0].intersection(&w[1]).count())
                .sum();
            shared as f64 / (sets.len() - 1) as f64
        };
        let (burst, steady, cold) =
            (mean_shared("burst"), mean_shared("steady"), mean_shared("coldstart"));
        assert!(burst > 1.3 * steady, "burst {burst:.2} !≫ steady {steady:.2}");
        assert!(steady > cold, "steady {steady:.2} !> coldstart {cold:.2}");
        assert!(burst > 10.0, "flash crowds must rank shared trending items: {burst:.2}");
    }

    #[test]
    fn zipf_popularity_causes_repeats() {
        let cfg = WorkloadConfig { duration_us: 10_000_000, ..Default::default() };
        let trace = generate(&cfg);
        let s = stats(&cfg, &trace);
        assert!(
            (s.distinct_users as f64) < trace.len() as f64 * 0.9,
            "expected user repeats: {s:?}"
        );
    }
}
