//! Binary arrival-trace record/replay (`relaygr trace record|replay`).
//!
//! Any scenario run is capturable as a compact little-endian file and
//! bit-identically replayable without regenerating the workload: the
//! file stores every [`GenRequest`] in stream order (ids, users, prefix
//! lengths and arrival times verbatim) plus the full [`WorkloadConfig`]
//! in its header, so candidate sets (request-id-keyed RNG), admission
//! seeding (scenario profile) and long/short classification all
//! reproduce exactly.  That makes giant runs diffable across PRs: record
//! once, replay under both engines, compare per-request outcomes.
//!
//! ## Format (version 1)
//!
//! ```text
//! magic "RGTR" | version u8 | record count u64 LE | config blob | records…
//! ```
//!
//! The config blob serializes every `WorkloadConfig` field in fixed
//! order (f64s as LE bit patterns, integers as LEB128 varints, the
//! scenario as a tag byte plus its parameters).  Each record is
//!
//! ```text
//! varint Δarrival_us | varint id | varint user | varint prefix_len | flags u8
//! ```
//!
//! with `Δarrival_us` the delta from the previous record's arrival time
//! (the stream is non-decreasing in arrival time, so deltas are small —
//! a steady 2k-QPS trace costs ~6 bytes/record).  The count field is
//! back-patched on [`TraceWriter::finish`], so recording streams in O(1)
//! memory; replay reads through one `BufReader`, also O(1).

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::workload::{GenRequest, ScenarioKind, WorkloadConfig};

const MAGIC: &[u8; 4] = b"RGTR";
const VERSION: u8 = 1;
/// Byte offset of the back-patched record count (after magic + version).
const COUNT_OFFSET: u64 = 5;

/// Handle to a recorded trace, carried inside [`WorkloadConfig::replay`]
/// so any engine entry point (`run_sim`, `run_reference`, the live
/// engine) can source arrivals from the file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplaySource {
    pub path: Arc<str>,
    pub records: u64,
}

// ---- varint / f64 primitives -------------------------------------------

pub(crate) fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        buf.push((v as u8) | 0x80);
        v >>= 7;
    }
    buf.push(v as u8);
}

fn put_f64(buf: &mut Vec<u8>, x: f64) {
    buf.extend_from_slice(&x.to_bits().to_le_bytes());
}

pub(crate) fn read_u8(r: &mut impl Read) -> io::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub(crate) fn read_varint(r: &mut impl Read) -> io::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = read_u8(r)?;
        if shift >= 64 || (shift == 63 && (b & 0x7F) > 1) {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "varint overflows u64"));
        }
        v |= u64::from(b & 0x7F) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn read_f64(r: &mut impl Read) -> io::Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_bits(u64::from_le_bytes(b)))
}

// ---- config blob --------------------------------------------------------

fn put_scenario(buf: &mut Vec<u8>, kind: &ScenarioKind) {
    match *kind {
        ScenarioKind::Steady => buf.push(0),
        ScenarioKind::Diurnal { amplitude, period_us } => {
            buf.push(1);
            put_f64(buf, amplitude);
            put_varint(buf, period_us);
        }
        ScenarioKind::Burst { start_frac, dur_frac, magnitude, hot_users } => {
            buf.push(2);
            put_f64(buf, start_frac);
            put_f64(buf, dur_frac);
            put_f64(buf, magnitude);
            put_varint(buf, hot_users);
        }
        ScenarioKind::Coldstart { cold_frac } => {
            buf.push(3);
            put_f64(buf, cold_frac);
        }
    }
}

fn read_scenario(r: &mut impl Read) -> io::Result<ScenarioKind> {
    Ok(match read_u8(r)? {
        0 => ScenarioKind::Steady,
        1 => ScenarioKind::Diurnal { amplitude: read_f64(r)?, period_us: read_varint(r)? },
        2 => ScenarioKind::Burst {
            start_frac: read_f64(r)?,
            dur_frac: read_f64(r)?,
            magnitude: read_f64(r)?,
            hot_users: read_varint(r)?,
        },
        3 => ScenarioKind::Coldstart { cold_frac: read_f64(r)? },
        t => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unknown scenario tag {t}"),
            ))
        }
    })
}

fn encode_config(cfg: &WorkloadConfig) -> Vec<u8> {
    let mut b = Vec::with_capacity(128);
    put_f64(&mut b, cfg.qps);
    put_varint(&mut b, cfg.duration_us);
    put_varint(&mut b, cfg.num_users);
    put_f64(&mut b, cfg.zipf_s);
    put_f64(&mut b, cfg.long_frac);
    put_varint(&mut b, cfg.long_threshold as u64);
    put_varint(&mut b, cfg.min_prefix as u64);
    put_varint(&mut b, cfg.max_prefix as u64);
    put_f64(&mut b, cfg.refresh_prob);
    put_varint(&mut b, cfg.refresh_burst_max as u64);
    put_varint(&mut b, cfg.refresh_gap_us.0);
    put_varint(&mut b, cfg.refresh_gap_us.1);
    // Option<usize> as value+1 (0 = None).
    put_varint(&mut b, cfg.fixed_long_len.map_or(0, |v| v as u64 + 1));
    put_scenario(&mut b, &cfg.scenario);
    put_varint(&mut b, cfg.cand_per_request as u64);
    put_varint(&mut b, cfg.cand_catalog);
    put_f64(&mut b, cfg.cand_zipf_s);
    b.extend_from_slice(&cfg.seed.to_le_bytes());
    b
}

fn decode_config(r: &mut impl Read) -> io::Result<WorkloadConfig> {
    let mut cfg = WorkloadConfig {
        qps: read_f64(r)?,
        duration_us: read_varint(r)?,
        num_users: read_varint(r)?,
        zipf_s: read_f64(r)?,
        long_frac: read_f64(r)?,
        long_threshold: read_varint(r)? as usize,
        min_prefix: read_varint(r)? as usize,
        max_prefix: read_varint(r)? as usize,
        refresh_prob: read_f64(r)?,
        refresh_burst_max: read_varint(r)? as usize,
        refresh_gap_us: (0, 0),
        fixed_long_len: None,
        scenario: ScenarioKind::Steady,
        cand_per_request: 0,
        cand_catalog: 0,
        cand_zipf_s: 0.0,
        seed: 0,
        replay: None,
    };
    cfg.refresh_gap_us = (read_varint(r)?, read_varint(r)?);
    cfg.fixed_long_len = match read_varint(r)? {
        0 => None,
        v => Some((v - 1) as usize),
    };
    cfg.scenario = read_scenario(r)?;
    cfg.cand_per_request = read_varint(r)? as usize;
    cfg.cand_catalog = read_varint(r)?;
    cfg.cand_zipf_s = read_f64(r)?;
    let mut seed = [0u8; 8];
    r.read_exact(&mut seed)?;
    cfg.seed = u64::from_le_bytes(seed);
    Ok(cfg)
}

// ---- writer -------------------------------------------------------------

/// Streaming trace writer: O(1) memory regardless of trace length.
pub struct TraceWriter {
    w: BufWriter<File>,
    prev_arrival: u64,
    count: u64,
    buf: Vec<u8>,
}

impl TraceWriter {
    pub fn create(path: &str, cfg: &WorkloadConfig) -> Result<TraceWriter> {
        let file = File::create(path).with_context(|| format!("creating trace '{path}'"))?;
        let mut w = BufWriter::new(file);
        w.write_all(MAGIC)?;
        w.write_all(&[VERSION])?;
        w.write_all(&0u64.to_le_bytes())?; // count, back-patched by finish()
        w.write_all(&encode_config(cfg))?;
        Ok(TraceWriter { w, prev_arrival: 0, count: 0, buf: Vec::with_capacity(32) })
    }

    pub fn push(&mut self, r: &GenRequest) -> Result<()> {
        debug_assert!(r.arrival_us >= self.prev_arrival, "stream order violated");
        self.buf.clear();
        put_varint(&mut self.buf, r.arrival_us - self.prev_arrival);
        put_varint(&mut self.buf, u64::from(r.id));
        put_varint(&mut self.buf, u64::from(r.user));
        put_varint(&mut self.buf, u64::from(r.prefix_len));
        self.buf.push(u8::from(r.is_refresh));
        self.w.write_all(&self.buf)?;
        self.prev_arrival = r.arrival_us;
        self.count += 1;
        Ok(())
    }

    /// Back-patch the record count and flush; returns (records, bytes).
    pub fn finish(mut self) -> Result<(u64, u64)> {
        self.w.flush()?;
        let file = self.w.get_mut();
        let bytes = file.stream_position()?;
        file.seek(SeekFrom::Start(COUNT_OFFSET))?;
        file.write_all(&self.count.to_le_bytes())?;
        file.flush()?;
        Ok((self.count, bytes))
    }
}

/// Record the configured scenario's full arrival stream to `path`.
/// Returns (records, bytes written).
pub fn record(path: &str, cfg: &WorkloadConfig) -> Result<(u64, u64)> {
    if cfg.replay.is_some() {
        bail!("refusing to re-record a replayed trace (replay source already set)");
    }
    let mut w = TraceWriter::create(path, cfg)?;
    for req in crate::workload::stream(cfg) {
        w.push(&req)?;
    }
    w.finish()
}

// ---- reader -------------------------------------------------------------

/// Parse a trace header: the recorded [`WorkloadConfig`] with
/// [`WorkloadConfig::replay`] pointing back at the file, ready to hand
/// to any engine entry point.
pub fn open_replay(path: &str) -> Result<WorkloadConfig> {
    let file = File::open(path).with_context(|| format!("opening trace '{path}'"))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("trace header truncated")?;
    if &magic != MAGIC {
        bail!("'{path}' is not a relaygr trace (bad magic)");
    }
    let version = read_u8(&mut r)?;
    if version != VERSION {
        bail!("trace '{path}' has unsupported version {version} (expected {VERSION})");
    }
    let mut count = [0u8; 8];
    r.read_exact(&mut count)?;
    let records = u64::from_le_bytes(count);
    let mut cfg = decode_config(&mut r).with_context(|| format!("trace '{path}' header"))?;
    cfg.replay = Some(ReplaySource { path: Arc::from(path), records });
    Ok(cfg)
}

/// Streaming record reader: one buffered file handle, O(1) memory.
/// Construction validates the header; mid-stream corruption panics with
/// context (the `Iterator` contract of [`super::ArrivalStream`] has no
/// error channel).
pub struct TraceReader {
    r: BufReader<File>,
    prev_arrival: u64,
    remaining: u64,
    path: Arc<str>,
}

impl std::fmt::Debug for TraceReader {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceReader")
            .field("path", &self.path)
            .field("remaining", &self.remaining)
            .finish()
    }
}

impl TraceReader {
    pub fn open(src: &ReplaySource) -> Result<TraceReader> {
        // Re-parse the header to position the reader at the first record
        // (also re-validates magic/version/count against `src`).
        let cfg = open_replay(&src.path)?;
        let recorded = cfg.replay.as_ref().map(|s| s.records).unwrap_or(0);
        if recorded != src.records {
            bail!(
                "trace '{}' changed on disk: header says {recorded} records, expected {}",
                src.path,
                src.records
            );
        }
        let file = File::open(src.path.as_ref())?;
        let mut r = BufReader::new(file);
        // Skip magic + version + count + config blob.
        let header_len = COUNT_OFFSET + 8 + encode_config(&cfg).len() as u64;
        r.seek(SeekFrom::Start(header_len))?;
        Ok(TraceReader {
            r,
            prev_arrival: 0,
            remaining: src.records,
            path: src.path.clone(),
        })
    }

    fn read_record(&mut self) -> io::Result<GenRequest> {
        let delta = read_varint(&mut self.r)?;
        let id = read_varint(&mut self.r)?;
        let user = read_varint(&mut self.r)?;
        let prefix_len = read_varint(&mut self.r)?;
        let flags = read_u8(&mut self.r)?;
        if id > u64::from(u32::MAX)
            || user > u64::from(u32::MAX)
            || prefix_len > u64::from(u32::MAX)
            || flags > 1
        {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "record field out of range"));
        }
        self.prev_arrival += delta;
        Ok(GenRequest {
            arrival_us: self.prev_arrival,
            id: id as u32,
            user: user as u32,
            prefix_len: prefix_len as u32,
            is_refresh: flags == 1,
        })
    }

    /// Next replayed request, or `None` once the recorded count is
    /// drained.  Panics (with path context) on a corrupt/truncated file.
    pub fn next_request(&mut self) -> Option<GenRequest> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        match self.read_record() {
            Ok(r) => Some(r),
            Err(e) => panic!("corrupt trace '{}': {e}", self.path),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, stream, ScenarioKind};

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("relaygr_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    fn small_cfg(kind: ScenarioKind) -> WorkloadConfig {
        WorkloadConfig {
            qps: 120.0,
            duration_us: 4_000_000,
            num_users: 5_000,
            refresh_prob: 0.6,
            scenario: kind,
            ..Default::default()
        }
    }

    #[test]
    fn varint_round_trips() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX / 3, u64::MAX] {
            let mut b = Vec::new();
            put_varint(&mut b, v);
            assert_eq!(read_varint(&mut b.as_slice()).unwrap(), v, "v={v}");
        }
        // Longest encoding is 10 bytes.
        let mut b = Vec::new();
        put_varint(&mut b, u64::MAX);
        assert_eq!(b.len(), 10);
    }

    #[test]
    fn config_blob_round_trips_all_scenarios() {
        for name in ScenarioKind::NAMES {
            let mut cfg = small_cfg(ScenarioKind::parse(name).unwrap());
            cfg.fixed_long_len = Some(4096);
            cfg.seed = 1234567;
            let blob = encode_config(&cfg);
            let back = decode_config(&mut blob.as_slice()).unwrap();
            // No PartialEq on WorkloadConfig; the Debug form covers every
            // field deterministically.
            assert_eq!(format!("{cfg:?}"), format!("{back:?}"), "{name}");
        }
    }

    #[test]
    fn record_replay_round_trips_every_scenario() {
        for name in ScenarioKind::NAMES {
            let cfg = small_cfg(ScenarioKind::parse(name).unwrap());
            let path = tmp(&format!("rt_{name}.trace"));
            let (n, bytes) = record(&path, &cfg).unwrap();
            let live = generate(&cfg);
            assert_eq!(n as usize, live.len(), "{name}");
            assert!(bytes > 0);
            let replay_cfg = open_replay(&path).unwrap();
            assert_eq!(replay_cfg.replay.as_ref().unwrap().records, n);
            // Replay must be bit-identical to the live stream — ids,
            // arrivals, users, prefix lengths, refresh flags.
            let replayed: Vec<_> = stream(&replay_cfg).collect();
            assert_eq!(replayed, live, "{name}");
            // And re-collecting replays identically (stateless reader).
            let again: Vec<_> = stream(&replay_cfg).collect();
            assert_eq!(again, live, "{name}: second replay");
        }
    }

    #[test]
    fn compact_encoding_beats_in_memory_record() {
        let cfg = small_cfg(ScenarioKind::Steady);
        let path = tmp("compact.trace");
        let (n, bytes) = record(&path, &cfg).unwrap();
        assert!(n > 100);
        // In-memory GenRequest is 24 bytes; on disk each record must
        // average well under half that (delta + varints).
        let per_record = (bytes as f64) / n as f64;
        assert!(per_record < 12.0, "{per_record:.1} bytes/record");
    }

    #[test]
    fn bad_files_are_rejected() {
        let path = tmp("bad.trace");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(open_replay(&path).is_err());
        std::fs::write(&path, b"RGTR\x63").unwrap();
        assert!(open_replay(&path).is_err(), "unsupported version");
        assert!(open_replay(&tmp("missing.trace")).is_err());
    }
}
