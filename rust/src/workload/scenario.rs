//! Named workload scenarios: one [`Scenario`] implementation per traffic
//! shape, all deterministic in `(WorkloadConfig, seed)`.
//!
//! * [`Steady`] — the paper's evaluation workload: homogeneous Poisson
//!   arrivals, Zipf user popularity, rapid-refresh bursts.  Bit-identical
//!   to the pre-scenario generator for a fixed seed.
//! * [`Diurnal`] — sinusoidally modulated QPS (day/night cycle): peaks
//!   stress admission control, troughs let lifecycles expire.
//! * [`Burst`] — a flash-crowd spike: during a window the offered rate
//!   multiplies and traffic concentrates on a hot-user subset, the
//!   worst case for affinity hot-spotting and reload concurrency.
//! * [`Coldstart`] — a high fraction of first-seen users (deploy/failover
//!   traffic): no short-term reuse to exploit, every admit is a fresh
//!   production.
//!
//! To add a fifth scenario: implement [`Scenario`], add a
//! [`ScenarioKind`] variant with its parameters, extend
//! [`ScenarioKind::parse`]/[`ScenarioKind::label`]/`as_scenario`, and it
//! is immediately selectable from `--scenario` in both engines (the
//! generators run before any engine state exists, so nothing else
//! changes).

use crate::util::rng::Rng;
use crate::workload::arrival::{ModulatedPoisson, Poisson};
use crate::workload::{user_prefix_len, GenRequest, WorkloadConfig};

/// Per-scenario candidate-overlap knobs: each candidate draw comes from
/// the `hot_items` most-popular head of the catalog with probability
/// `hot_frac`, otherwise from the whole catalog.  Flash crowds rank the
/// same trending items over and over (the segment cache's best case);
/// coldstart traffic barely overlaps (its worst case).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CandidateProfile {
    pub hot_items: u64,
    pub hot_frac: f64,
}

/// Per-scenario initial operating point for the closed-loop admission
/// controller (mirrors [`CandidateProfile`]): where the risk margin and
/// the admitted-rate multiplier start before the windowed estimators
/// warm up.  Flash crowds open the rate and tighten the margin up front
/// (the spike outruns any estimator); coldstart traffic starts
/// conservative (no reuse to exploit, every admit is a fresh
/// production).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionProfile {
    /// Initial effective risk headroom (at-risk iff est > h·budget).
    pub headroom_init: f64,
    /// Initial admitted-rate multiplier over Q_m·M.
    pub rate_mult_init: f64,
}

/// A workload scenario: turns a [`WorkloadConfig`] into an arrival trace
/// — streamed lazily through [`ArrivalStream`] (the simulator consumes
/// it request by request, O(live refreshes) memory) or materialized by
/// [`Scenario::generate`] (which just collects the stream, so both views
/// are bit-identical by construction).
pub trait Scenario {
    fn name(&self) -> &'static str;
    /// The scenario's streaming arrival source, in `(arrival_us, id)`
    /// order.
    fn stream(&self, cfg: &WorkloadConfig) -> ArrivalStream;
    /// Materialize the full arrival trace, sorted by `(arrival_us, id)`.
    fn generate(&self, cfg: &WorkloadConfig) -> Vec<GenRequest> {
        self.stream(cfg).collect()
    }
}

/// Scenario selector carried in [`WorkloadConfig`] (CLI: `--scenario`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScenarioKind {
    Steady,
    Diurnal { amplitude: f64, period_us: u64 },
    Burst { start_frac: f64, dur_frac: f64, magnitude: f64, hot_users: u64 },
    Coldstart { cold_frac: f64 },
}

impl Default for ScenarioKind {
    fn default() -> Self {
        ScenarioKind::Steady
    }
}

impl ScenarioKind {
    /// The four named scenarios with their default parameters.
    pub const NAMES: [&'static str; 4] = ["steady", "diurnal", "burst", "coldstart"];

    pub fn parse(s: &str) -> Result<ScenarioKind, String> {
        match s {
            "steady" => Ok(ScenarioKind::Steady),
            "diurnal" => Ok(ScenarioKind::Diurnal { amplitude: 0.6, period_us: 10_000_000 }),
            "burst" => Ok(ScenarioKind::Burst {
                start_frac: 0.4,
                dur_frac: 0.1,
                magnitude: 5.0,
                hot_users: 64,
            }),
            "coldstart" => Ok(ScenarioKind::Coldstart { cold_frac: 0.6 }),
            other => Err(format!(
                "unknown scenario '{other}' (available: {})",
                Self::NAMES.join(", ")
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ScenarioKind::Steady => "steady",
            ScenarioKind::Diurnal { .. } => "diurnal",
            ScenarioKind::Burst { .. } => "burst",
            ScenarioKind::Coldstart { .. } => "coldstart",
        }
    }

    pub fn as_scenario(&self) -> Box<dyn Scenario> {
        match *self {
            ScenarioKind::Steady => Box::new(Steady),
            ScenarioKind::Diurnal { amplitude, period_us } => {
                Box::new(Diurnal { amplitude, period_us })
            }
            ScenarioKind::Burst { start_frac, dur_frac, magnitude, hot_users } => {
                Box::new(Burst { start_frac, dur_frac, magnitude, hot_users })
            }
            ScenarioKind::Coldstart { cold_frac } => Box::new(Coldstart { cold_frac }),
        }
    }

    /// The scenario's candidate-overlap knobs (see [`CandidateProfile`]):
    /// how strongly concurrent requests' candidate sets overlap, on top
    /// of the global Zipf item popularity (`--zipf`).
    pub fn candidate_profile(&self) -> CandidateProfile {
        match self {
            ScenarioKind::Steady => CandidateProfile { hot_items: 512, hot_frac: 0.2 },
            ScenarioKind::Diurnal { .. } => CandidateProfile { hot_items: 512, hot_frac: 0.35 },
            // Flash crowd: everyone ranks the same trending items.
            ScenarioKind::Burst { .. } => CandidateProfile { hot_items: 64, hot_frac: 0.8 },
            // First-seen users bring long-tail candidates.
            ScenarioKind::Coldstart { .. } => CandidateProfile { hot_items: 4096, hot_frac: 0.05 },
        }
    }

    /// The scenario's initial admission operating point (see
    /// [`AdmissionProfile`]), seeded into the adaptive controller at run
    /// start by both engines; explicit `--headroom-init` /
    /// `--rate-mult-init` choices win.
    pub fn admission_profile(&self) -> AdmissionProfile {
        match self {
            ScenarioKind::Steady => {
                AdmissionProfile { headroom_init: 0.8, rate_mult_init: 0.5 }
            }
            ScenarioKind::Diurnal { .. } => {
                AdmissionProfile { headroom_init: 0.75, rate_mult_init: 0.6 }
            }
            // Flash crowd: tighten the risk margin and open the rate
            // before the estimators can catch up with the spike.
            ScenarioKind::Burst { .. } => {
                AdmissionProfile { headroom_init: 0.65, rate_mult_init: 1.0 }
            }
            // First-seen users: conservative until reuse materialises.
            ScenarioKind::Coldstart { .. } => {
                AdmissionProfile { headroom_init: 0.9, rate_mult_init: 0.4 }
            }
        }
    }

    /// Expected number of base (non-refresh) requests this scenario
    /// offers — the rate-conservation contract the property tests check.
    /// Parameters are clamped exactly as the generators clamp them.
    pub fn expected_base_requests(&self, cfg: &WorkloadConfig) -> f64 {
        let dur_s = cfg.duration_us as f64 / 1e6;
        match *self {
            ScenarioKind::Steady | ScenarioKind::Coldstart { .. } => cfg.qps * dur_s,
            ScenarioKind::Diurnal { amplitude, period_us } => {
                // ∫ qps·(1 + a·sin(2πt/T)) dt = qps·dur + qps·a·T/2π·(1 - cos(2π·dur/T)).
                let a = amplitude.clamp(0.0, 1.0);
                let w = 2.0 * std::f64::consts::PI / period_us.max(1) as f64;
                let residual = cfg.qps * a / w * (1.0 - (w * cfg.duration_us as f64).cos());
                cfg.qps * dur_s + residual / 1e6
            }
            ScenarioKind::Burst { start_frac, dur_frac, magnitude, .. } => {
                // The window is truncated at the end of the trace.
                let start = start_frac.clamp(0.0, 1.0);
                let window = dur_frac.clamp(0.0, 1.0).min(1.0 - start);
                cfg.qps * dur_s * (1.0 + (magnitude.max(1.0) - 1.0) * window)
            }
        }
    }
}

/// One scenario's base-arrival process: the `(arrival_us, user)` pairs of
/// the non-refresh requests, in arrival order, consuming the stream's
/// shared RNG in exactly the order the batch generators did — that RNG
/// discipline is what keeps streamed traces bit-identical to the legacy
/// materialized ones (pinned by `steady_matches_legacy_generator_bit_for_bit`).
enum BaseProcess {
    Steady(Poisson),
    Diurnal(ModulatedPoisson<Box<dyn Fn(f64) -> f64>>),
    Burst { arrivals: ModulatedPoisson<Box<dyn Fn(f64) -> f64>>, start: u64, end: u64, hot: u64 },
    Coldstart { arrivals: Poisson, cold_frac: f64, cold_next: u64 },
}

impl BaseProcess {
    fn next(&mut self, rng: &mut Rng, cfg: &WorkloadConfig) -> Option<(u64, u64)> {
        match self {
            BaseProcess::Steady(arrivals) => {
                if arrivals.time_us() >= cfg.duration_us {
                    return None;
                }
                let arrival = arrivals.next(rng);
                if arrival >= cfg.duration_us {
                    return None;
                }
                Some((arrival, rng.zipf(cfg.num_users, cfg.zipf_s) - 1))
            }
            BaseProcess::Diurnal(arrivals) => {
                let arrival = arrivals.next(rng, cfg.duration_us)?;
                Some((arrival, rng.zipf(cfg.num_users, cfg.zipf_s) - 1))
            }
            BaseProcess::Burst { arrivals, start, end, hot } => {
                let arrival = arrivals.next(rng, cfg.duration_us)?;
                let user = if arrival >= *start && arrival < *end {
                    rng.zipf(*hot, cfg.zipf_s) - 1
                } else {
                    rng.zipf(cfg.num_users, cfg.zipf_s) - 1
                };
                Some((arrival, user))
            }
            BaseProcess::Coldstart { arrivals, cold_frac, cold_next } => {
                if arrivals.time_us() >= cfg.duration_us {
                    return None;
                }
                let arrival = arrivals.next(rng);
                if arrival >= cfg.duration_us {
                    return None;
                }
                let user = if rng.bernoulli(*cold_frac) {
                    let u = *cold_next;
                    *cold_next += 1;
                    u
                } else {
                    rng.zipf(cfg.num_users, cfg.zipf_s) - 1
                };
                Some((arrival, user))
            }
        }
    }
}

/// Pending-heap entry ordered by the trace sort key `(arrival_us, id)`.
#[derive(PartialEq, Eq)]
struct PendingReq(GenRequest);

impl Ord for PendingReq {
    fn cmp(&self, other: &PendingReq) -> std::cmp::Ordering {
        (self.0.arrival_us, self.0.id).cmp(&(other.0.arrival_us, other.0.id))
    }
}

impl PartialOrd for PendingReq {
    fn partial_cmp(&self, other: &PendingReq) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Lazy arrival generator: yields the scenario's requests one at a time
/// in `(arrival_us, id)` order — the exact order of the materialized
/// trace — holding only the not-yet-due refresh bursts in memory
/// (O(live) instead of O(trace) at million-user scale).
///
/// Why the emission order is exact: base arrivals are generated in
/// non-decreasing time order, ids in generation order, and a refresh is
/// generated (with an id between its base's and the next base's) strictly
/// at or after its base's arrival.  So once a base at time `t` has been
/// generated, every pending request with `arrival_us <= t` precedes all
/// not-yet-generated requests in `(arrival_us, id)` order — those all
/// have `arrival_us >= t` *and* larger ids — and can be emitted.
pub struct ArrivalStream {
    cfg: WorkloadConfig,
    rng: Rng,
    base: BaseProcess,
    pending: std::collections::BinaryHeap<std::cmp::Reverse<PendingReq>>,
    next_id: u64,
    last_base_t: u64,
    exhausted: bool,
    /// Replay mode: arrivals come verbatim from a recorded trace and the
    /// generator machinery above is bypassed entirely.
    replay: Option<crate::workload::trace::TraceReader>,
}

impl ArrivalStream {
    fn new(cfg: &WorkloadConfig, base: BaseProcess) -> ArrivalStream {
        ArrivalStream {
            cfg: cfg.clone(),
            rng: Rng::new(cfg.seed),
            base,
            pending: std::collections::BinaryHeap::new(),
            next_id: 0,
            last_base_t: 0,
            exhausted: false,
            replay: None,
        }
    }

    /// A stream that replays `src` verbatim (same ids, arrivals, users —
    /// so candidate sets and every downstream decision reproduce
    /// bit-identically), holding O(1) memory: one buffered file reader.
    pub fn replay(cfg: &WorkloadConfig, src: &crate::workload::trace::ReplaySource) -> Self {
        let reader = crate::workload::trace::TraceReader::open(src)
            .unwrap_or_else(|e| panic!("opening replay trace '{}': {e}", src.path));
        let mut s = ArrivalStream::new(cfg, BaseProcess::Steady(Poisson::new(1.0)));
        s.replay = Some(reader);
        s
    }

    fn emit(&mut self, arrival_us: u64, user: u64, prefix_len: usize, is_refresh: bool) {
        let id = self.next_id;
        self.next_id += 1;
        // The u32 id/user budget is guarded at config parse
        // (`config::workload_config`); these asserts catch generators
        // driven past it without going through the CLI path.
        assert!(id <= u32::MAX as u64, "request id {id} overflows the u32 id budget");
        assert!(user <= u32::MAX as u64, "user id {user} overflows the u32 id budget");
        self.pending.push(std::cmp::Reverse(PendingReq(GenRequest {
            arrival_us,
            id: id as u32,
            user: user as u32,
            prefix_len: prefix_len.min(u32::MAX as usize) as u32,
            is_refresh,
        })));
    }

    /// Generate one base request plus its rapid-refresh burst — exactly
    /// the legacy generator's per-arrival body, same RNG call order.
    fn refill(&mut self) {
        let Some((arrival, user)) = self.base.next(&mut self.rng, &self.cfg) else {
            self.exhausted = true;
            return;
        };
        self.last_base_t = arrival;
        let prefix_len = user_prefix_len(&self.cfg, user);
        self.emit(arrival, user, prefix_len, false);
        // Rapid-refresh bursts: same user again shortly after — the
        // short-term cross-request reuse the DRAM tier targets.
        if prefix_len > self.cfg.long_threshold && self.rng.bernoulli(self.cfg.refresh_prob) {
            let burst = 1 + self.rng.range(0, self.cfg.refresh_burst_max);
            let mut rt = arrival;
            for _ in 0..burst {
                rt += self
                    .rng
                    .range(self.cfg.refresh_gap_us.0 as usize, self.cfg.refresh_gap_us.1 as usize)
                    as u64;
                if rt >= self.cfg.duration_us {
                    break;
                }
                self.emit(rt, user, prefix_len, true);
            }
        }
    }
}

impl Iterator for ArrivalStream {
    type Item = GenRequest;

    fn next(&mut self) -> Option<GenRequest> {
        if let Some(reader) = &mut self.replay {
            return reader.next_request();
        }
        loop {
            if let Some(std::cmp::Reverse(min)) = self.pending.peek() {
                if self.exhausted || min.0.arrival_us <= self.last_base_t {
                    return self.pending.pop().map(|std::cmp::Reverse(p)| p.0);
                }
            } else if self.exhausted {
                return None;
            }
            self.refill();
        }
    }
}

/// Today's behaviour: homogeneous Poisson + Zipf popularity.
pub struct Steady;

impl Scenario for Steady {
    fn name(&self) -> &'static str {
        "steady"
    }

    fn stream(&self, cfg: &WorkloadConfig) -> ArrivalStream {
        ArrivalStream::new(cfg, BaseProcess::Steady(Poisson::new(cfg.qps)))
    }
}

/// Sinusoidal QPS: λ(t) = qps·(1 + a·sin(2πt/T)).
pub struct Diurnal {
    pub amplitude: f64,
    pub period_us: u64,
}

impl Scenario for Diurnal {
    fn name(&self) -> &'static str {
        "diurnal"
    }

    fn stream(&self, cfg: &WorkloadConfig) -> ArrivalStream {
        let amp = self.amplitude.clamp(0.0, 1.0);
        let period = self.period_us.max(1) as f64;
        let qps = cfg.qps;
        let arrivals = ModulatedPoisson::new(
            qps * (1.0 + amp),
            Box::new(move |t_us: f64| {
                qps * (1.0 + amp * (2.0 * std::f64::consts::PI * t_us / period).sin())
            }) as Box<dyn Fn(f64) -> f64>,
        );
        ArrivalStream::new(cfg, BaseProcess::Diurnal(arrivals))
    }
}

/// Flash crowd: inside `[start, start+dur)` the rate multiplies by
/// `magnitude` and users concentrate on the `hot_users` most popular ids.
pub struct Burst {
    pub start_frac: f64,
    pub dur_frac: f64,
    pub magnitude: f64,
    pub hot_users: u64,
}

impl Scenario for Burst {
    fn name(&self) -> &'static str {
        "burst"
    }

    fn stream(&self, cfg: &WorkloadConfig) -> ArrivalStream {
        let start = (cfg.duration_us as f64 * self.start_frac.clamp(0.0, 1.0)) as u64;
        let end = start + (cfg.duration_us as f64 * self.dur_frac.clamp(0.0, 1.0)) as u64;
        let magnitude = self.magnitude.max(1.0);
        let qps = cfg.qps;
        let arrivals = ModulatedPoisson::new(
            qps * magnitude,
            Box::new(move |t_us: f64| {
                let t = t_us as u64;
                if t >= start && t < end {
                    qps * magnitude
                } else {
                    qps
                }
            }) as Box<dyn Fn(f64) -> f64>,
        );
        let hot = self.hot_users.clamp(1, cfg.num_users);
        ArrivalStream::new(cfg, BaseProcess::Burst { arrivals, start, end, hot })
    }
}

/// Deploy/failover traffic: with probability `cold_frac` a request comes
/// from a never-before-seen user (ids beyond the warm population), so
/// caches cannot help until their first lifecycle completes.
pub struct Coldstart {
    pub cold_frac: f64,
}

impl Scenario for Coldstart {
    fn name(&self) -> &'static str {
        "coldstart"
    }

    fn stream(&self, cfg: &WorkloadConfig) -> ArrivalStream {
        ArrivalStream::new(
            cfg,
            BaseProcess::Coldstart {
                arrivals: Poisson::new(cfg.qps),
                cold_frac: self.cold_frac.clamp(0.0, 1.0),
                cold_next: cfg.num_users, // fresh ids, disjoint from warm
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::stats;

    fn cfg(kind: ScenarioKind) -> WorkloadConfig {
        WorkloadConfig {
            qps: 250.0,
            duration_us: 20_000_000,
            num_users: 20_000,
            scenario: kind,
            ..Default::default()
        }
    }

    #[test]
    fn parse_round_trips_labels() {
        for name in ScenarioKind::NAMES {
            let kind = ScenarioKind::parse(name).unwrap();
            assert_eq!(kind.label(), name);
            assert_eq!(kind.as_scenario().name(), name);
        }
        assert!(ScenarioKind::parse("lunar").is_err());
    }

    #[test]
    fn admission_profiles_are_sane_and_scenario_shaped() {
        for name in ScenarioKind::NAMES {
            let p = ScenarioKind::parse(name).unwrap().admission_profile();
            assert!((0.0..=1.0).contains(&p.headroom_init), "{name}: {p:?}");
            assert!((0.0..=1.0).contains(&p.rate_mult_init), "{name}: {p:?}");
        }
        let steady = ScenarioKind::Steady.admission_profile();
        let burst = ScenarioKind::parse("burst").unwrap().admission_profile();
        let cold = ScenarioKind::parse("coldstart").unwrap().admission_profile();
        // Flash crowds open the rate and tighten the margin up front;
        // coldstart starts more conservative than steady on both axes.
        assert!(burst.rate_mult_init > steady.rate_mult_init);
        assert!(burst.headroom_init < steady.headroom_init);
        assert!(cold.headroom_init > steady.headroom_init);
        assert!(cold.rate_mult_init < steady.rate_mult_init);
    }

    #[test]
    fn stream_emits_in_trace_order_with_contiguous_ids() {
        // The sim consumes arrivals lazily; the stream's emission order
        // must equal the materialized trace's `(arrival_us, id)` sort
        // order exactly, with no request dropped or duplicated — the
        // flush rule (emit once the base clock passes a pending refresh)
        // is what this pins.
        for name in ScenarioKind::NAMES {
            let kind = ScenarioKind::parse(name).unwrap();
            let mut c = cfg(kind);
            c.refresh_prob = 0.7; // dense refresh bursts stress the heap
            let streamed: Vec<GenRequest> = kind.as_scenario().stream(&c).collect();
            assert!(!streamed.is_empty());
            let mut sorted = streamed.clone();
            sorted.sort_by_key(|r| (r.arrival_us, r.id));
            assert_eq!(streamed, sorted, "{name}: stream out of (arrival, id) order");
            let mut ids: Vec<u32> = streamed.iter().map(|r| r.id).collect();
            ids.sort_unstable();
            assert_eq!(
                ids,
                (0..streamed.len() as u32).collect::<Vec<_>>(),
                "{name}: ids must be contiguous — nothing dropped in flight"
            );
        }
    }

    #[test]
    fn burst_concentrates_on_hot_users() {
        let kind = ScenarioKind::parse("burst").unwrap();
        let c = cfg(kind);
        let trace = kind.as_scenario().generate(&c);
        let ScenarioKind::Burst { start_frac, dur_frac, hot_users, .. } = kind else {
            unreachable!()
        };
        let start = (c.duration_us as f64 * start_frac) as u64;
        let end = start + (c.duration_us as f64 * dur_frac) as u64;
        let in_window: Vec<_> = trace
            .iter()
            .filter(|r| !r.is_refresh && r.arrival_us >= start && r.arrival_us < end)
            .collect();
        assert!(!in_window.is_empty());
        assert!(
            in_window.iter().all(|r| u64::from(r.user) < hot_users),
            "window hits hot subset only"
        );
        // The window rate clearly exceeds the background rate.
        let out_count = trace
            .iter()
            .filter(|r| !r.is_refresh && (r.arrival_us < start || r.arrival_us >= end))
            .count();
        let window_frac = (end - start) as f64 / c.duration_us as f64;
        let in_rate = in_window.len() as f64 / window_frac;
        let out_rate = out_count as f64 / (1.0 - window_frac);
        assert!(in_rate > 2.5 * out_rate, "in {in_rate:.0} vs out {out_rate:.0}");
    }

    #[test]
    fn coldstart_floods_first_seen_users() {
        let kind = ScenarioKind::parse("coldstart").unwrap();
        let c = cfg(kind);
        let trace = kind.as_scenario().generate(&c);
        let cold = trace
            .iter()
            .filter(|r| !r.is_refresh && u64::from(r.user) >= c.num_users)
            .count();
        let base = trace.iter().filter(|r| !r.is_refresh).count();
        let frac = cold as f64 / base as f64;
        assert!((frac - 0.6).abs() < 0.05, "cold fraction {frac:.2}");
        // Cold ids are unique — genuinely first-seen.
        let mut cold_ids: Vec<u64> = trace
            .iter()
            .filter(|r| !r.is_refresh && u64::from(r.user) >= c.num_users)
            .map(|r| u64::from(r.user))
            .collect();
        let n = cold_ids.len();
        cold_ids.sort_unstable();
        cold_ids.dedup();
        assert_eq!(cold_ids.len(), n);
    }

    #[test]
    fn diurnal_modulates_rate_over_phases() {
        let kind = ScenarioKind::Diurnal { amplitude: 0.8, period_us: 20_000_000 };
        let c = cfg(kind);
        let trace = kind.as_scenario().generate(&c);
        // One full period over the trace: first half (sin ≥ 0) must carry
        // clearly more traffic than the second half.
        let half = c.duration_us / 2;
        let first =
            trace.iter().filter(|r| !r.is_refresh && r.arrival_us < half).count() as f64;
        let second =
            trace.iter().filter(|r| !r.is_refresh && r.arrival_us >= half).count() as f64;
        assert!(first > 1.5 * second, "first {first} vs second {second}");
        let s = stats(&c, &trace);
        assert!(s.requests > 0 && s.mean_prefix > 0.0);
    }
}
