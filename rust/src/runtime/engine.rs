//! PJRT execution engine: loads HLO-text artifacts, compiles them once,
//! and executes them from the serving hot path.
//!
//! The ψ handoff is the load-bearing part: `execute_prefix_to_device`
//! leaves the per-layer KV cache as an **on-device buffer** (`KvBuffer`)
//! and `execute_rank_cached` feeds it straight back into the rank
//! executable via `execute_b` — the in-HBM residency of the paper's
//! relay race, with no host round-trip on the ranking critical path.
//! Spilling to the hierarchy's DRAM tier is an explicit `to_host` /
//! `from_host` pair, mirroring the D2H/H2D cost the paper accounts for.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::model::ModelSpec;
use crate::runtime::artifacts::{ArtifactRecord, FnKind, Manifest};

/// Wrapper making the PJRT types shareable across worker threads.
///
/// SAFETY: the PJRT CPU client and loaded executables are internally
/// thread-safe (XLA's CPU client serializes compilation and supports
/// concurrent `Execute`); the `xla` crate just never declared the auto
/// traits because of the raw pointers it holds.
struct SendExe(xla::PjRtLoadedExecutable);
unsafe impl Send for SendExe {}
unsafe impl Sync for SendExe {}

struct SendClient(xla::PjRtClient);
unsafe impl Send for SendClient {}
unsafe impl Sync for SendClient {}

/// Device-resident ψ (or any single-array output) handle.
pub struct KvBuffer {
    buf: xla::PjRtBuffer,
    /// Logical element count (f32).
    pub elements: usize,
    /// Logical footprint in bytes, used for HBM accounting.
    pub bytes: usize,
}
unsafe impl Send for KvBuffer {}
unsafe impl Sync for KvBuffer {}

impl KvBuffer {
    /// D2H: copy ψ to host memory (hierarchy spill).
    pub fn to_host(&self) -> Result<Vec<f32>> {
        let lit = self.buf.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }
}

/// One compiled model entry point.
pub struct LoadedModel {
    pub artifact: ArtifactRecord,
    exe: SendExe,
    client: SendClient,
}

impl LoadedModel {
    fn literal_from(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("input has {} elements, shape {:?} needs {n}", data.len(), shape);
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    fn check_arity(&self, got: usize) -> Result<()> {
        if got != self.artifact.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {got}",
                self.artifact.name,
                self.artifact.inputs.len()
            );
        }
        Ok(())
    }

    /// Execute entirely through host literals; returns the flat f32 output.
    pub fn execute_host(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        self.check_arity(inputs.len())?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&self.artifact.inputs)
            .map(|(data, spec)| Self::literal_from(data, &spec.shape))
            .collect::<Result<Vec<_>>>()?;
        let out = self.exe.0.execute::<xla::Literal>(&literals)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Execute and keep the (single-array) output on device — used for
    /// `prefix` so ψ never leaves HBM.
    pub fn execute_to_device(&self, inputs: &[&[f32]]) -> Result<KvBuffer> {
        self.check_arity(inputs.len())?;
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .zip(&self.artifact.inputs)
            .map(|(data, spec)| {
                self.client
                    .0
                    .buffer_from_host_buffer::<f32>(data, &spec.shape, None)
                    .map_err(|e| anyhow!("h2d: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let mut out = self.exe.0.execute_b(&bufs)?;
        let buf = out.remove(0).remove(0);
        let elements = self.artifact.outputs[0].elements();
        Ok(KvBuffer { buf, elements, bytes: elements * 4 })
    }

    /// Execute `rank` with a device-resident ψ as input 0 and host data for
    /// the remaining inputs (incremental tokens, candidate items).
    pub fn execute_with_kv(&self, kv: &KvBuffer, rest: &[&[f32]]) -> Result<Vec<f32>> {
        self.check_arity(1 + rest.len())?;
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + rest.len());
        let host_bufs: Vec<xla::PjRtBuffer> = rest
            .iter()
            .zip(&self.artifact.inputs[1..])
            .map(|(data, spec)| {
                self.client
                    .0
                    .buffer_from_host_buffer::<f32>(data, &spec.shape, None)
                    .map_err(|e| anyhow!("h2d: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        bufs.push(&kv.buf);
        bufs.extend(host_bufs.iter());
        let out = self.exe.0.execute_b(&bufs)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// H2D: re-materialise a spilled ψ on device (hierarchy reload).
    pub fn kv_from_host(&self, data: &[f32]) -> Result<KvBuffer> {
        let spec = &self.artifact.inputs[0];
        if data.len() != spec.elements() {
            bail!("kv reload: {} elements, expected {}", data.len(), spec.elements());
        }
        let buf = self
            .client
            .0
            .buffer_from_host_buffer::<f32>(data, &spec.shape, None)
            .map_err(|e| anyhow!("h2d: {e:?}"))?;
        Ok(KvBuffer { buf, elements: data.len(), bytes: data.len() * 4 })
    }
}

/// Compile-once executable pool over an artifact directory.
pub struct Engine {
    client: SendClient,
    pub manifest: Manifest,
    models: Mutex<HashMap<String, Arc<LoadedModel>>>,
}

impl Engine {
    /// Create a PJRT CPU client and index the artifact directory.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { client: SendClient(client), manifest, models: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    /// Get (compiling on first use) the executable for `kind` × `spec`.
    pub fn model(&self, kind: FnKind, spec: &ModelSpec) -> Result<Arc<LoadedModel>> {
        let artifact = self
            .manifest
            .find(kind, spec)
            .ok_or_else(|| {
                anyhow!("no artifact for {} {} — regenerate with `make artifacts`", kind.as_str(), spec.name())
            })?
            .clone();
        self.model_for(artifact)
    }

    pub fn model_by_name(&self, name: &str) -> Result<Arc<LoadedModel>> {
        let artifact = self
            .manifest
            .find_by_name(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))?
            .clone();
        self.model_for(artifact)
    }

    fn model_for(&self, artifact: ArtifactRecord) -> Result<Arc<LoadedModel>> {
        if let Some(m) = self.models.lock().unwrap().get(&artifact.name) {
            return Ok(m.clone());
        }
        // Compile outside the lock: compilation can take seconds and other
        // variants should not block on it.
        let path = self.manifest.hlo_path(&artifact);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", artifact.name))?;
        let model = Arc::new(LoadedModel {
            artifact,
            exe: SendExe(exe),
            client: SendClient(self.client.0.clone()),
        });
        let mut map = self.models.lock().unwrap();
        let entry = map.entry(model.artifact.name.clone()).or_insert_with(|| model.clone());
        Ok(entry.clone())
    }

    /// Eagerly compile all three entry points of a variant (warm-up).
    pub fn warm(&self, spec: &ModelSpec) -> Result<()> {
        for kind in [FnKind::Prefix, FnKind::Rank, FnKind::Full] {
            if self.manifest.find(kind, spec).is_some() {
                self.model(kind, spec)?;
            }
        }
        Ok(())
    }

    /// Number of compiled executables currently pooled.
    pub fn pooled(&self) -> usize {
        self.models.lock().unwrap().len()
    }
}

/// Deterministic synthetic embedding generator standing in for the
/// production embedding service: user/item ids hash to stable vectors.
pub fn synth_embedding(seed: u64, rows: usize, dim: usize, scale: f32) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed ^ 0x5eed_e18e_dd1e_5eed);
    rng.normal_vec_f32(rows * dim, scale)
}
