//! PJRT runtime: artifact manifest + compile-once execution engine.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py` and
//! executes them on the PJRT CPU client. ψ stays on device between the
//! prefix and rank executions ([`engine::KvBuffer`]).

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactRecord, FnKind, Manifest, TensorSpec};
pub use engine::{synth_embedding, Engine, KvBuffer, LoadedModel};
