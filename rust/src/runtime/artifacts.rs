//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json` + `*.hlo.txt`) and the rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::{Dtype, ModelSpec, ModelType};
use crate::util::json::Json;

/// Which of the three AOT entry points an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FnKind {
    /// Pre-inference: behaviour prefix → ψ.
    Prefix,
    /// Ranking-on-cache: ψ + incremental + items → scores.
    Rank,
    /// Baseline full inline inference.
    Full,
}

impl FnKind {
    pub fn parse(s: &str) -> Result<FnKind> {
        match s {
            "prefix" => Ok(FnKind::Prefix),
            "rank" => Ok(FnKind::Rank),
            "full" => Ok(FnKind::Full),
            other => bail!("unknown fn kind '{other}'"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FnKind::Prefix => "prefix",
            FnKind::Rank => "rank",
            FnKind::Full => "full",
        }
    }
}

/// Tensor shape+dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled model entry point.
#[derive(Debug, Clone)]
pub struct ArtifactRecord {
    pub name: String,
    pub fn_kind: FnKind,
    /// File name within the artifact directory.
    pub file: String,
    pub sha256: String,
    pub spec: ModelSpec,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub jax_version: String,
    pub artifacts: Vec<ArtifactRecord>,
}

fn parse_spec(cfg: &Json) -> Result<ModelSpec> {
    let model_type = ModelType::from_index(cfg.req_usize("model_type")?)
        .ok_or_else(|| anyhow!("bad model_type"))?;
    let dtype = match cfg.req_str("dtype")? {
        "float32" => Dtype::F32,
        "float16" | "bfloat16" => Dtype::F16,
        other => bail!("unsupported dtype '{other}'"),
    };
    Ok(ModelSpec {
        model_type,
        layers: cfg.req_usize("layers")?,
        dim: cfg.req_usize("dim")?,
        heads: cfg.req_usize("heads")?,
        prefix_len: cfg.req_usize("prefix_len")?,
        incr_len: cfg.req_usize("incr_len")?,
        num_items: cfg.req_usize("num_items")?,
        dtype,
    })
}

fn parse_tensors(arr: &[Json]) -> Result<Vec<TensorSpec>> {
    arr.iter()
        .map(|t| {
            let shape = t
                .req_array("shape")?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { shape, dtype: t.req_str("dtype")?.to_string() })
        })
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text).context("parsing manifest.json")?;
        let jax_version = root.get("jax_version").and_then(Json::as_str).unwrap_or("?").to_string();
        let mut artifacts = Vec::new();
        for a in root.req_array("artifacts")? {
            let cfg = a.get("config").ok_or_else(|| anyhow!("artifact missing config"))?;
            artifacts.push(ArtifactRecord {
                name: a.req_str("name")?.to_string(),
                fn_kind: FnKind::parse(a.req_str("fn")?)?,
                file: a.req_str("path")?.to_string(),
                sha256: a.get("sha256").and_then(Json::as_str).unwrap_or("").to_string(),
                spec: parse_spec(cfg)?,
                inputs: parse_tensors(a.req_array("inputs")?)?,
                outputs: parse_tensors(a.req_array("outputs")?)?,
            });
        }
        Ok(Manifest { dir, jax_version, artifacts })
    }

    /// All distinct model variants (by spec name), stable order.
    pub fn variants(&self) -> Vec<ModelSpec> {
        let mut seen = Vec::new();
        for a in &self.artifacts {
            if !seen.contains(&a.spec) {
                seen.push(a.spec);
            }
        }
        seen
    }

    /// Find the artifact implementing `kind` for the given variant.
    pub fn find(&self, kind: FnKind, spec: &ModelSpec) -> Option<&ArtifactRecord> {
        self.artifacts.iter().find(|a| a.fn_kind == kind && &a.spec == spec)
    }

    pub fn find_by_name(&self, name: &str) -> Option<&ArtifactRecord> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Path of an artifact's HLO text on disk.
    pub fn hlo_path(&self, a: &ArtifactRecord) -> PathBuf {
        self.dir.join(&a.file)
    }

    /// The variant with the largest prefix bucket (for demos) or a named one.
    pub fn default_variant(&self) -> Option<ModelSpec> {
        self.variants().into_iter().max_by_key(|s| (s.model_type.index() == 1) as usize * s.prefix_len)
    }

    /// A variant sized for *live* CPU-PJRT serving (closest to a 512-token
    /// prefix): interpret-mode attention on multi-K prefixes costs
    /// hundreds of ms per call, far past the pipeline budgets.
    pub fn live_variant(&self) -> Option<ModelSpec> {
        self.variants()
            .into_iter()
            .min_by_key(|s| (s.prefix_len as i64 - 512).unsigned_abs() + s.dim as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "jax_version": "0.9",
      "artifacts": [
        {"name": "prefix_t1_L2_D32_H2_S128_I64_N64", "fn": "prefix",
         "path": "prefix_t1_L2_D32_H2_S128_I64_N64.hlo.txt", "sha256": "ab",
         "config": {"model_type": 1, "layers": 2, "dim": 32, "heads": 2,
                    "prefix_len": 128, "incr_len": 64, "num_items": 64,
                    "dtype": "float32", "seed": 0, "head_dim": 16,
                    "kv_bytes": 65536, "name": "t1_L2_D32_H2_S128_I64_N64"},
         "inputs": [{"shape": [128, 32], "dtype": "float32"}],
         "outputs": [{"shape": [2, 2, 2, 128, 16], "dtype": "float32"}]},
        {"name": "rank_t1_L2_D32_H2_S128_I64_N64", "fn": "rank",
         "path": "rank_t1_L2_D32_H2_S128_I64_N64.hlo.txt", "sha256": "cd",
         "config": {"model_type": 1, "layers": 2, "dim": 32, "heads": 2,
                    "prefix_len": 128, "incr_len": 64, "num_items": 64,
                    "dtype": "float32", "seed": 0, "head_dim": 16,
                    "kv_bytes": 65536, "name": "t1_L2_D32_H2_S128_I64_N64"},
         "inputs": [{"shape": [2, 2, 2, 128, 16], "dtype": "float32"},
                     {"shape": [64, 32], "dtype": "float32"},
                     {"shape": [64, 32], "dtype": "float32"}],
         "outputs": [{"shape": [64], "dtype": "float32"}]}
      ]
    }"#;

    #[test]
    fn parse_sample_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.variants().len(), 1);
        let spec = m.variants()[0];
        assert_eq!(spec.prefix_len, 128);
        assert_eq!(spec.kv_bytes(), 2 * 2 * 128 * 32 * 4);
        let a = m.find(FnKind::Rank, &spec).unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].elements(), 2 * 2 * 2 * 128 * 16);
        assert!(m.find(FnKind::Full, &spec).is_none());
    }

    #[test]
    fn fn_kind_roundtrip() {
        for k in [FnKind::Prefix, FnKind::Rank, FnKind::Full] {
            assert_eq!(FnKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(FnKind::parse("decode").is_err());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#, PathBuf::new()).is_err());
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
    }
}
