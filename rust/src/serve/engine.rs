//! Live serving engine: the relay-race coordinator running for real —
//! worker threads per instance, real PJRT executions (the AOT artifacts),
//! device-resident ψ buffers in an HBM window, host-memory DRAM tier,
//! wall-clock metrics.
//!
//! Every caching/placement/admission decision is made by the shared
//! [`RelayCoordinator`] — the same state machine the discrete-event
//! simulator drives.  This module is a compute adapter: it translates
//! coordinator actions into real PJRT executions, H2D/D2H transfers and
//! condvar waits, and reports completions back through the coordinator's
//! event API.  Used by the examples, by `relaygr serve`, and by
//! `relaygr calibrate` to fit the simulator's CPU cost profile.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::RunMetrics;
use crate::model::ModelSpec;
use crate::relay::baseline::Mode;
use crate::relay::cell::{CellConfig, CellPickerKind, CellReq, CellScenario, CellSet};
use crate::relay::coordinator::{
    BatchDecision, CoordinatorConfig, QueuedReload, RankAction, RelayCoordinator, ReqId,
    SignalAction, Stage,
};
use crate::relay::fault::FaultConfig;
use crate::relay::pipeline::{CacheOutcome, Lifecycle, PipelineConfig, StageSampler};
use crate::relay::router::RouterConfig;
use crate::relay::segment::SegmentConfig;
use crate::relay::tier::{EvictPolicy, TierConfig};
use crate::relay::trigger::{AdmissionConfig, BehaviorMeta, TriggerConfig};
use crate::runtime::{synth_embedding, Engine, FnKind, KvBuffer, LoadedModel};
use crate::util::rng::Rng;
use crate::workload::{GenRequest, WorkloadConfig};

/// Cache payload: device-resident in HBM, host copy in the DRAM tier.
#[derive(Clone)]
pub enum Payload {
    Device(Arc<KvBuffer>),
    Host(Arc<Vec<f32>>),
}

/// The coordinator installs candidate segments with the payload default
/// (the live rank kernel does not export per-item KV slices, so segment
/// entries are accounting-level placeholders on this engine).
impl Default for Payload {
    fn default() -> Payload {
        Payload::Host(Arc::new(Vec::new()))
    }
}

/// Live-engine configuration.
#[derive(Clone)]
pub struct LiveConfig {
    pub artifacts_dir: String,
    pub spec: ModelSpec,
    pub mode: Mode,
    pub n_instances: usize,
    pub m_slots: usize,
    /// HBM window per instance (bytes of ψ).
    pub hbm_bytes: usize,
    pub max_reload_concurrency: usize,
    pub long_threshold: usize,
    pub pipeline: PipelineConfig,
    /// Scale factor on retrieval/preproc sleeps (1.0 = production-mirror).
    pub stage_scale: f64,
    /// Wait budget for ψ production before falling back (µs).
    pub wait_budget_us: u64,
    /// Eviction policy for the mode-selected DRAM tier (`--dram-policy`).
    pub dram_policy: EvictPolicy,
    /// Explicit lower-tier stack override (`--tier`); `None` derives a
    /// single tier from the serving mode's DRAM capacity.
    pub tiers: Option<Vec<TierConfig>>,
    /// Fraction of the HBM window carved out for the candidate-segment
    /// cache (`--segment-cache`; 0 = disabled).
    pub segment_frac: f64,
    /// Staleness bound for cached candidate segments.
    pub seg_ttl_us: u64,
    /// Admission-control mode + closed-loop knobs (`--admission`).
    pub admission: AdmissionConfig,
    /// Microbatch window for the coordinator's batch former
    /// (`--batch-window`, µs; 0 = unbatched).
    pub batch_window_us: u64,
    /// Maximum members per batched rank pass (`--batch-max`).
    pub batch_max: usize,
    /// Coordinator cells (`--cells`; 1 = the single pre-cell pool,
    /// decision-identical to it).  Must divide `n_instances`.
    pub cells: usize,
    /// Level-1 cell picker (`--cell-picker affinity|spread`).
    pub cell_picker: CellPickerKind,
    /// Affinity locality-vs-load knob (`--cell-spill`).
    pub cell_spill: f64,
    /// Flight-recorder span retention (`--trace-spans`; 0 = tracing off).
    /// Observe-only: decisions are bit-identical either way.
    pub trace_spans: usize,
    /// JSONL metrics-heartbeat sink for `relaygr serve` (`--heartbeat`;
    /// `None` = no heartbeat).
    pub heartbeat_path: Option<String>,
    /// Heartbeat emission interval, milliseconds (`--heartbeat-ms`).
    pub heartbeat_ms: u64,
    /// Fault-injection plan (`--faults`; default off).  Scheduled crash
    /// events are sim/reference-only — wall-clock runs have no fixed
    /// duration to anchor `crash@P%` against.
    pub faults: FaultConfig,
    pub seed: u64,
}

impl LiveConfig {
    pub fn new(artifacts_dir: &str, spec: ModelSpec, mode: Mode) -> LiveConfig {
        LiveConfig {
            artifacts_dir: artifacts_dir.to_string(),
            spec,
            mode,
            n_instances: 2,
            m_slots: 2,
            hbm_bytes: 256 << 20,
            max_reload_concurrency: 2,
            long_threshold: spec.prefix_len.saturating_sub(1),
            pipeline: PipelineConfig::default(),
            stage_scale: 1.0,
            wait_budget_us: 200_000,
            dram_policy: EvictPolicy::Lru,
            tiers: None,
            segment_frac: 0.0,
            seg_ttl_us: 3_000_000,
            admission: AdmissionConfig::default(),
            batch_window_us: 0,
            batch_max: 32,
            cells: 1,
            cell_picker: CellPickerKind::Affinity,
            cell_spill: 2.0,
            trace_spans: 0,
            heartbeat_path: None,
            heartbeat_ms: 1_000,
            faults: FaultConfig::default(),
            seed: 42,
        }
    }

    /// The lower-tier stack this deployment induces (see
    /// [`Mode::tier_stack`] for the precedence rule).
    pub fn tier_stack(&self) -> Vec<TierConfig> {
        self.mode.tier_stack(self.dram_policy, self.tiers.as_deref())
    }

    /// The coordinator configuration this deployment shape induces.
    pub fn coordinator_config(&self) -> CoordinatorConfig {
        let is_baseline = matches!(self.mode, Mode::Baseline);
        let spec = self.spec;
        CoordinatorConfig {
            mode: self.mode,
            router: RouterConfig {
                n_instances: self.n_instances,
                servers: self.n_instances,
                r2: if is_baseline {
                    0.0
                } else {
                    (1.0 / self.n_instances as f64).max(0.45)
                },
                max_special_per_server: 1,
                gateways: 2,
                vnodes: 32,
                normal_policy: crate::relay::router::BalancePolicy::LeastConnections,
            },
            trigger: TriggerConfig {
                rank_p99_budget_us: self.pipeline.rank_budget_us,
                headroom: 0.8,
                t_life_us: self.pipeline.t_life_us,
                kv_p99_bytes: self.spec.kv_bytes(),
                hbm_bytes: self.hbm_bytes,
                // Full slice regardless of the segment partition: the ψ
                // window enforces its budget locally, and admission must
                // not shift between reuse-on and reuse-off runs.
                r1: 1.0,
                q_m: 1000.0,
                m_slots: self.m_slots,
                r2: 0.5,
                n_instances: self.n_instances,
                // Filled in by the coordinator from `batch_window_us`
                // and the fault plan's retry pricing.
                batch_window_us: 0,
                retry_budget_us: 0,
                admission: self.admission.clone(),
            },
            tiers: self.tier_stack(),
            long_threshold: self.long_threshold,
            t_life_us: self.pipeline.t_life_us,
            max_reload_concurrency: self.max_reload_concurrency,
            hbm_bytes: self.hbm_bytes,
            dim: self.spec.dim,
            kv_bytes: Box::new(move |_| spec.kv_bytes()),
            segment: SegmentConfig {
                frac: self.segment_frac,
                ttl_us: self.seg_ttl_us,
                seg_bytes: self.spec.segment_bytes(),
                version: 0,
                tiers: Vec::new(),
            },
            batch_window_us: self.batch_window_us,
            batch_max: self.batch_max,
            trace_spans: self.trace_spans,
            faults: {
                // Fold the run seed so identical `--faults` specs draw
                // identically across engines and job counts.
                let mut f = self.faults.clone();
                f.seed = self.seed;
                f
            },
        }
    }

    /// The cluster-shape half of the cell layer (the live engine runs no
    /// scripted churn — wall-clock runs have no fixed duration to script
    /// against; use the sim/reference engines for scenario figures).
    pub fn cell_config(&self) -> CellConfig {
        CellConfig {
            cells: self.cells,
            picker: self.cell_picker,
            spill_ratio: self.cell_spill,
            scenario: CellScenario::None,
            // Passed through for validation; the duration-0 event
            // compile above means no crash ever fires on this engine.
            crash: self.faults.crash,
        }
    }

    /// The coordinator configuration for ONE cell: the deployment shape
    /// with the instance pool split evenly across cells.  With
    /// `cells == 1` this IS [`LiveConfig::coordinator_config`].
    pub fn cell_coordinator_config(&self) -> CoordinatorConfig {
        let mut per = self.clone();
        per.n_instances = self.n_instances / self.cells.max(1);
        per.coordinator_config()
    }
}

/// The cell set (coordinator shards) shared by the request driver and
/// every worker thread.
struct Shared {
    cells: Mutex<CellSet<Payload>>,
    /// Instances per cell: global instance id = cell × this + local.
    inst_per_cell: usize,
    cv: Condvar,
    /// Per-instance rank passes held by the coordinator's batch former:
    /// the response channel (and reload accounting) whoever flushes the
    /// batch needs to complete each member.  Entries are stashed in the
    /// same cell-set critical section as their `offer_rank`, so a flush
    /// (which closes the batch under the cell-set lock first) always
    /// finds all of its members here.  Lock order: `cells` → `pending`,
    /// everywhere.
    pending: Mutex<Vec<Vec<PendingRank>>>,
}

impl Shared {
    /// `(cell, cell-local instance)` of a global instance id.
    fn locate(&self, instance: usize) -> (usize, usize) {
        (instance / self.inst_per_cell, instance % self.inst_per_cell)
    }
}

/// A rank pass stashed while its microbatch forms.
struct PendingRank {
    req: GenRequest,
    handle: ReqId,
    resp: Sender<RankDone>,
    load_us: f64,
}

enum Work {
    /// Compute ψ for `user` and report `on_psi_ready`.
    PreInfer { user: u64 },
    /// Signal-initiated DRAM→HBM reload for `user`.
    Reload { user: u64 },
    /// Rank `req`; `handle` is the coordinator's [`ReqId`] issued at
    /// arrival.
    Rank { req: GenRequest, handle: ReqId, resp: Sender<RankDone> },
    Stop,
}

struct RankDone {
    outcome: CacheOutcome,
    admitted: bool,
    rank_us: f64,
    load_us: f64,
    wait_us: f64,
    scores: Vec<f32>,
}

/// One live ranking instance: m_slots worker threads over a shared queue.
pub struct LiveInstance {
    pub id: usize,
    tx: Sender<Work>,
    workers: Vec<std::thread::JoinHandle<()>>,
    busy_us: Arc<AtomicU64>,
}

struct Models {
    prefix: Arc<LoadedModel>,
    rank: Arc<LoadedModel>,
    full: Arc<LoadedModel>,
}

impl LiveInstance {
    fn spawn(id: usize, cfg: &LiveConfig, models: Arc<Models>, shared: Arc<Shared>) -> LiveInstance {
        let (tx, rx) = channel::<Work>();
        let rx = Arc::new(Mutex::new(rx));
        let busy_us = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::new();
        for _ in 0..cfg.m_slots {
            let rx = rx.clone();
            let shared = shared.clone();
            let models = models.clone();
            let cfg = cfg.clone();
            let busy = busy_us.clone();
            workers.push(std::thread::spawn(move || loop {
                let work = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match work {
                    Ok(Work::PreInfer { user }) => {
                        Self::do_pre_infer(user, id, &cfg, &models, &shared, &busy);
                    }
                    Ok(Work::Reload { user }) => {
                        Self::perform_reload(user, id, &models, &shared);
                    }
                    Ok(Work::Rank { req, handle, resp }) => {
                        Self::do_rank(&req, handle, resp, id, &cfg, &models, &shared, &busy);
                    }
                    Ok(Work::Stop) | Err(_) => break,
                }
            }));
        }
        LiveInstance { id, tx, workers, busy_us }
    }

    /// The admitted pre-infer side path (§3.2): behaviour fetch +
    /// embedding + the prefix pass on device, then `on_psi_ready`.
    /// (The pseudo-pre-infer checks already ran in `on_trigger_check`.)
    fn do_pre_infer(
        user: u64,
        instance: usize,
        cfg: &LiveConfig,
        models: &Models,
        shared: &Shared,
        busy: &Arc<AtomicU64>,
    ) {
        let prefix = synth_embedding(user ^ 1, cfg.spec.prefix_len, cfg.spec.dim, 0.5);
        let t0 = Instant::now();
        let result = models.prefix.execute_to_device(&[&prefix]);
        busy.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        let payload = match result {
            Ok(kv) => Some(Payload::Device(Arc::new(kv))),
            Err(e) => {
                log::warn!("pre-infer failed for user {user}: {e:#}");
                None
            }
        };
        let (cell, li) = shared.locate(instance);
        let mut cells = shared.cells.lock().unwrap();
        cells.coord_mut(cell).on_psi_ready(now_us(), li, user, payload);
        shared.cv.notify_all();
    }

    /// Perform one DRAM→HBM reload (real H2D), report it, and drain any
    /// queued reloads this completion unblocks.
    fn perform_reload(user: u64, instance: usize, models: &Models, shared: &Shared) {
        let (cell, li) = shared.locate(instance);
        let mut current = Some(user);
        while let Some(u) = current.take() {
            let host = {
                let mut cells = shared.cells.lock().unwrap();
                cells.coord_mut(cell).dram_payload(li, u)
            };
            let (payload, bytes) = match host {
                Some((bytes, Payload::Host(data))) => {
                    let device = match models.rank.kv_from_host(&data) {
                        Ok(kv) => Some(Payload::Device(Arc::new(kv))),
                        Err(e) => {
                            log::warn!("reload H2D failed for {u}: {e:#}");
                            None
                        }
                    };
                    (device, bytes)
                }
                _ => (None, 0),
            };
            let mut cells = shared.cells.lock().unwrap();
            let res = cells.coord_mut(cell).on_reload_done(now_us(), li, u, payload, bytes);
            shared.cv.notify_all();
            let mut next = res.next;
            // Grant queued reloads their turn; aborted ones release their
            // waiters and pass the slot on.
            while let Some(nu) = next {
                match cells.coord_mut(cell).begin_queued_reload(now_us(), li, nu) {
                    QueuedReload::Start { .. } => {
                        drop(cells);
                        current = Some(nu);
                        break;
                    }
                    QueuedReload::Aborted { next: n2, .. } => {
                        shared.cv.notify_all();
                        next = n2;
                    }
                }
            }
            if current.is_some() {
                continue;
            }
            break;
        }
    }

    /// Classify + wait-resolve one rank pass, then hand it to the
    /// instance's batch former.  `Solo` executes inline; otherwise the
    /// pass (with its response channel) is stashed in `Shared::pending`
    /// and whoever flushes the batch — the worker that filled it, or the
    /// opener waiting out the window — executes every member and sends
    /// each response.  Decision-plane batching only: segment planning
    /// and pricing are batch-aware in the coordinator/cost model, while
    /// PJRT still executes one member at a time (the rank artifact has
    /// no batched entry point).
    #[allow(clippy::too_many_arguments)]
    fn do_rank(
        req: &GenRequest,
        handle: ReqId,
        resp: Sender<RankDone>,
        instance: usize,
        cfg: &LiveConfig,
        models: &Models,
        shared: &Shared,
        busy: &Arc<AtomicU64>,
    ) {
        let user = req.uid();
        let (cell, li) = shared.locate(instance);
        let mut load_us = 0.0;
        let wait_start = Instant::now();

        let mut cells = shared.cells.lock().unwrap();
        match cells.coord_mut(cell).on_rank_start(now_us(), handle) {
            RankAction::Proceed { .. } => {}
            RankAction::StartReload { .. } => {
                // Perform the H2D inline on this worker (it holds a
                // reload-concurrency slot); `on_reload_done` resolves us.
                drop(cells);
                let t0 = Instant::now();
                Self::perform_reload(user, instance, models, shared);
                load_us = t0.elapsed().as_micros() as f64;
                cells = shared.cells.lock().unwrap();
            }
            RankAction::Wait | RankAction::WaitReload => loop {
                if cells.coord(cell).wait_resolved(handle) {
                    break;
                }
                if wait_start.elapsed().as_micros() as u64 > cfg.wait_budget_us {
                    // Wait-budget fallback: classify and stop waiting.
                    cells.coord_mut(cell).on_wait_timeout(now_us(), handle);
                    break;
                }
                let (g, _t) = shared
                    .cv
                    .wait_timeout(cells, Duration::from_millis(5))
                    .expect("condvar poisoned");
                cells = g;
            },
        }
        match cells.coord_mut(cell).offer_rank(now_us(), handle) {
            BatchDecision::Solo => {
                drop(cells);
                let done = Self::exec_rank(req, handle, cell, load_us, cfg, models, shared, busy);
                let _ = resp.send(done);
            }
            BatchDecision::Opened { deadline, gen } => {
                // Stash under the cell-set lock (lock order cells →
                // pending) so the batch cannot close before its member is
                // findable.
                shared.pending.lock().unwrap()[instance].push(PendingRank {
                    req: *req,
                    handle,
                    resp,
                    load_us,
                });
                // This worker is the window leader: hold the window open
                // on the condvar, then flush — unless a `Filled` flush
                // got there first (stale generation).
                loop {
                    if !cells.coord(cell).batch_open(li, gen) {
                        drop(cells);
                        return;
                    }
                    let now = now_us();
                    if now >= deadline {
                        break;
                    }
                    let (g, _t) = shared
                        .cv
                        .wait_timeout(cells, Duration::from_micros(deadline - now))
                        .expect("condvar poisoned");
                    cells = g;
                }
                drop(cells);
                Self::flush_batch(instance, gen, cfg, models, shared, busy);
            }
            BatchDecision::Joined => {
                shared.pending.lock().unwrap()[instance].push(PendingRank {
                    req: *req,
                    handle,
                    resp,
                    load_us,
                });
                drop(cells);
            }
            BatchDecision::Filled { gen } => {
                shared.pending.lock().unwrap()[instance].push(PendingRank {
                    req: *req,
                    handle,
                    resp,
                    load_us,
                });
                drop(cells);
                Self::flush_batch(instance, gen, cfg, models, shared, busy);
            }
        }
    }

    /// Close batch `gen` on `instance` and execute every member,
    /// sending each stashed response.  Stale generations are a no-op
    /// (the batch was already flushed).
    fn flush_batch(
        instance: usize,
        gen: u64,
        cfg: &LiveConfig,
        models: &Models,
        shared: &Shared,
        busy: &Arc<AtomicU64>,
    ) {
        let (cell, li) = shared.locate(instance);
        let mut members: Vec<ReqId> = Vec::new();
        let drained: Vec<PendingRank> = {
            let mut cells = shared.cells.lock().unwrap();
            if !cells.coord_mut(cell).close_batch(now_us(), li, gen, &mut members) {
                return;
            }
            drop(cells);
            let mut pending = shared.pending.lock().unwrap();
            let q = &mut pending[instance];
            let mut out = Vec::with_capacity(members.len());
            for &h in &members {
                if let Some(pos) = q.iter().position(|p| p.handle == h) {
                    out.push(q.swap_remove(pos));
                }
            }
            out
        };
        shared.cv.notify_all(); // wake a window leader whose batch went stale
        for p in drained {
            let done =
                Self::exec_rank(&p.req, p.handle, cell, p.load_us, cfg, models, shared, busy);
            let _ = p.resp.send(done);
        }
    }

    /// Execute one classified rank pass: consume ψ + plan segments, run
    /// the PJRT execution, and close out the request.
    #[allow(clippy::too_many_arguments)]
    fn exec_rank(
        req: &GenRequest,
        handle: ReqId,
        cell: usize,
        load_us: f64,
        cfg: &LiveConfig,
        models: &Models,
        shared: &Shared,
        busy: &Arc<AtomicU64>,
    ) -> RankDone {
        let user = req.uid();
        let incr = synth_embedding(user ^ 2, cfg.spec.incr_len, cfg.spec.dim, 0.5);
        let items = synth_embedding(req.rid() ^ 3, cfg.spec.num_items, cfg.spec.dim, 0.5);
        // Consume ψ at execution start.
        let mut cells = shared.cells.lock().unwrap();
        let rc = cells.coord_mut(cell).rank_compute(now_us(), handle);
        let mut kv: Option<Payload> = rc.payload;
        if rc.cached && !matches!(kv, Some(Payload::Device(_))) {
            // Classified cached but no device buffer materialised: run the
            // safe fallback and make the metrics reflect it.
            cells.coord_mut(cell).force_fallback(now_us(), handle);
            kv = None;
        }
        drop(cells);

        // Execute ranking.
        let t0 = Instant::now();
        let scores = match &kv {
            Some(Payload::Device(buf)) => {
                models.rank.execute_with_kv(buf, &[&incr, &items]).unwrap_or_default()
            }
            _ => {
                let prefix = synth_embedding(user ^ 1, cfg.spec.prefix_len, cfg.spec.dim, 0.5);
                models.full.execute_host(&[&prefix, &incr, &items]).unwrap_or_default()
            }
        };
        let rank_us = t0.elapsed().as_micros() as f64;
        busy.fetch_add(rank_us as u64, Ordering::Relaxed);

        // Close out: release the connection + admitted slot and classify
        // the spill lifecycle.
        let kv_bytes = match &kv {
            Some(Payload::Device(buf)) => buf.bytes,
            _ => cfg.spec.kv_bytes(),
        };
        let mut cells = shared.cells.lock().unwrap();
        // Through the cell layer, not the coordinator directly — the
        // wrapper is what counts cross-cell ψ misses on completion.
        let done = cells.on_rank_done(now_us(), CellReq { cell, id: handle }, kv_bytes);
        drop(cells);
        if done.spill.is_some() {
            // Spill fresh ψ to DRAM (D2H, off the critical path) and slide
            // the HBM window.
            if let Some(Payload::Device(buf)) = &kv {
                match buf.to_host() {
                    Ok(host) => {
                        let mut cells = shared.cells.lock().unwrap();
                        cells.coord_mut(cell).complete_spill(
                            now_us(),
                            done.instance,
                            user,
                            buf.bytes,
                            Payload::Host(Arc::new(host)),
                        );
                    }
                    Err(e) => log::warn!("spill D2H failed for {user}: {e:#}"),
                }
            }
        }
        let wait_us = (done.wait_us - load_us).max(0.0);
        RankDone {
            outcome: done.outcome,
            admitted: done.admitted,
            rank_us,
            load_us,
            wait_us,
            scores,
        }
    }

    fn stop(self) {
        let _ = self.tx.send(Work::Stop);
        for _ in 1..self.workers.len() {
            let _ = self.tx.send(Work::Stop);
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn now_us() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap().as_micros() as u64
}

/// The live cluster: the shared coordinator + per-instance worker pools.
pub struct LiveCluster {
    pub cfg: LiveConfig,
    engine: Arc<Engine>,
    instances: Vec<LiveInstance>,
    shared: Arc<Shared>,
    start: Instant,
}

impl LiveCluster {
    pub fn start(cfg: LiveConfig) -> Result<LiveCluster> {
        let engine = Arc::new(Engine::load(&cfg.artifacts_dir)?);
        let models = Arc::new(Models {
            prefix: engine.model(FnKind::Prefix, &cfg.spec)?,
            rank: engine.model(FnKind::Rank, &cfg.spec)?,
            full: engine.model(FnKind::Full, &cfg.spec)?,
        });
        let threshold = cfg.long_threshold;
        anyhow::ensure!(
            cfg.cells >= 1 && cfg.n_instances % cfg.cells == 0,
            "--cells {} must be >= 1 and divide the {} instances",
            cfg.cells,
            cfg.n_instances,
        );
        let coords = (0..cfg.cells)
            .map(|_| {
                RelayCoordinator::new(cfg.cell_coordinator_config(), |_| {
                    Box::new(move |m: &BehaviorMeta| {
                        // Live risk test: long prefixes are at risk by
                        // construction.
                        if m.prefix_len > threshold {
                            1e9
                        } else {
                            0.0
                        }
                    })
                })
            })
            .collect::<Result<Vec<_>>>()?;
        // No scripted churn on the wall clock — duration 0 compiles the
        // `None` scenario to an empty event list.
        let cells = CellSet::new(cfg.cell_config(), coords, 0)?;
        let shared = Arc::new(Shared {
            cells: Mutex::new(cells),
            inst_per_cell: cfg.n_instances / cfg.cells,
            cv: Condvar::new(),
            pending: Mutex::new((0..cfg.n_instances).map(|_| Vec::new()).collect()),
        });
        let instances = (0..cfg.n_instances)
            .map(|id| LiveInstance::spawn(id, &cfg, models.clone(), shared.clone()))
            .collect();
        Ok(LiveCluster { cfg, engine, instances, shared, start: Instant::now() })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Drive one request through retrieval → preproc → ranking with real
    /// sleeps and real execution; returns its lifecycle.
    pub fn drive_request(&self, req: GenRequest, rng: &mut Rng) -> Result<Lifecycle> {
        self.drive_request_with(req, &[], rng)
    }

    /// Like [`LiveCluster::drive_request`], carrying the request's
    /// candidate item set for segment planning (empty = no reuse).
    pub fn drive_request_with(
        &self,
        req: GenRequest,
        candidates: &[u64],
        rng: &mut Rng,
    ) -> Result<Lifecycle> {
        let t0 = Instant::now();
        // Two-level routing: the cell layer picks the serving cell, then
        // the in-cell coordinator owns every downstream decision.  All
        // instance indices it returns are cell-local; workers are
        // addressed by global id.
        let (handle, wants_trigger) = {
            let mut cells = self.shared.cells.lock().unwrap();
            cells.on_arrival(now_us(), req.rid(), req.uid(), req.plen(), candidates)
        };
        let base = handle.cell * self.shared.inst_per_cell;
        if wants_trigger {
            // Trigger side path (metadata only); admitted work is handed
            // to the chosen instance's worker pool.
            let action = {
                let mut cells = self.shared.cells.lock().unwrap();
                cells.coord_mut(handle.cell).on_trigger_check(now_us(), handle.id)
            };
            match action {
                SignalAction::Produce { instance, user, .. } => {
                    let _ = self.instances[base + instance].tx.send(Work::PreInfer { user });
                }
                SignalAction::Reload { instance, user, .. } => {
                    let _ = self.instances[base + instance].tx.send(Work::Reload { user });
                }
                SignalAction::None => {}
            }
        }
        let retrieval = StageSampler::from_mean_p99(
            self.cfg.pipeline.retrieval_mean_us,
            self.cfg.pipeline.retrieval_p99_us,
        );
        let preproc = StageSampler::from_mean_p99(
            self.cfg.pipeline.preproc_mean_us,
            self.cfg.pipeline.preproc_p99_us,
        );
        sleep_us(retrieval.sample(rng) * self.cfg.stage_scale);
        let retrieval_done = t0.elapsed().as_micros() as u64;
        {
            let mut cells = self.shared.cells.lock().unwrap();
            cells.coord_mut(handle.cell).on_stage_done(now_us(), handle.id, Stage::Retrieval);
        }
        sleep_us(preproc.sample(rng) * self.cfg.stage_scale);
        let preproc_done = t0.elapsed().as_micros() as u64;

        // Late binding: the coordinator resolves the ranking instance
        // (cell-local; mapped to the global worker id).
        let inst = {
            let mut cells = self.shared.cells.lock().unwrap();
            cells
                .coord_mut(handle.cell)
                .on_stage_done(now_us(), handle.id, Stage::Preproc)
                .expect("preproc resolves the ranking instance")
        };
        let inst = base + inst;
        let (tx, rx): (Sender<RankDone>, Receiver<RankDone>) = channel();
        self.instances[inst]
            .tx
            .send(Work::Rank { req, handle: handle.id, resp: tx })
            .map_err(|_| anyhow!("instance {inst} stopped"))?;
        let done = rx.recv().map_err(|_| anyhow!("rank worker dropped response"))?;
        let done_us = t0.elapsed().as_micros() as u64;
        anyhow::ensure!(!done.scores.is_empty(), "empty scores from rank execution");
        Ok(Lifecycle {
            request: req.rid(),
            user: req.uid(),
            prefix_len: req.plen(),
            arrival_us: 0,
            retrieval_done_us: retrieval_done,
            preproc_done_us: preproc_done,
            rank_start_us: preproc_done,
            done_us,
            pre_us: 0.0,
            load_us: done.load_us,
            rank_us: done.rank_us,
            wait_us: done.wait_us,
            outcome: done.outcome,
            admitted: done.admitted,
            instance: inst,
        })
    }

    /// One JSONL heartbeat line: wall-clock offset plus an interval
    /// snapshot of completion, trigger, hierarchy, segment and batch
    /// counters.  Append-only observer — reads the same stats accessors
    /// the end-of-run block does, decides nothing.
    fn emit_heartbeat(
        &self,
        out: &mut std::fs::File,
        elapsed: Duration,
        metrics: &Mutex<RunMetrics>,
    ) {
        use std::io::Write;
        let (completed, outcomes) = {
            let m = metrics.lock().unwrap();
            (m.completed, m.outcome_counts)
        };
        // Cluster-wide snapshot: merge every cell's counters so the
        // heartbeat line keeps its PR 8 shape regardless of `--cells`.
        let cells = self.shared.cells.lock().unwrap();
        let mut in_flight = 0usize;
        let mut t = cells.coord(0).trigger_stats();
        let mut h = cells.coord(0).hierarchy_stats();
        let mut s = cells.coord(0).segment_stats();
        let mut batch = [0u64; 5];
        let mut spans = (0u64, 0u64);
        for c in 0..cells.n_cells() {
            let coord = cells.coord(c);
            in_flight += coord.live_requests();
            if c > 0 {
                t.merge(coord.trigger_stats());
                h.merge(coord.hierarchy_stats());
                s.merge(coord.segment_stats());
            }
            if let Some(fl) = coord.flight() {
                for (acc, n) in batch.iter_mut().zip(fl.batch_counts) {
                    *acc += n;
                }
                spans.0 += fl.emitted();
                spans.1 += fl.dropped();
            }
        }
        drop(cells);
        let outcome_fields = crate::metrics::OUTCOME_NAMES
            .iter()
            .zip(outcomes)
            .map(|(n, c)| format!("\"{n}\":{c}"))
            .collect::<Vec<_>>()
            .join(",");
        let line = format!(
            "{{\"t_ms\":{},\"completed\":{completed},\"in_flight\":{in_flight},\
\"outcomes\":{{{outcome_fields}}},\
\"trigger\":{{\"assessed\":{},\"admitted\":{},\"rate_limited\":{},\"footprint_limited\":{}}},\
\"hierarchy\":{{\"hbm_hits\":{},\"dram_hits\":{},\"misses\":{},\"reloads\":{},\"spills\":{}}},\
\"segments\":{{\"lookups\":{},\"reused\":{},\"joined\":{},\"produced\":{}}},\
\"batch\":{{\"opened\":{},\"joined\":{},\"filled\":{},\"flushed\":{},\"solo\":{}}},\
\"spans\":{{\"emitted\":{},\"dropped\":{}}}}}",
            elapsed.as_millis(),
            t.assessed,
            t.admitted,
            t.rate_limited,
            t.footprint_limited,
            h.hbm_hits,
            h.dram_hits,
            h.misses,
            h.reloads_started + h.reloads_joined + h.reloads_queued,
            h.spills,
            s.lookups,
            s.reused,
            s.joined,
            s.produced,
            batch[0],
            batch[1],
            batch[2],
            batch[3],
            batch[4],
            spans.0,
            spans.1,
        );
        if let Err(e) = writeln!(out, "{line}") {
            log::warn!("heartbeat write failed: {e}");
        }
    }

    /// Run a whole trace open-loop; returns aggregated metrics.
    pub fn run_trace(&self, wl: &WorkloadConfig) -> Result<RunMetrics> {
        let trace = crate::workload::generate(wl);
        let mut metrics = RunMetrics::new(self.cfg.pipeline.pipeline_slo_us);
        metrics.scenario = wl.scenario.label().to_string();
        let metrics = Mutex::new(metrics);
        let seg_on = { self.shared.cells.lock().unwrap().coord(0).segments_enabled() };
        let mut heartbeat = match self.cfg.heartbeat_path.as_deref() {
            Some(p) => Some(
                std::fs::File::create(p)
                    .map_err(|e| anyhow!("creating heartbeat sink '{p}': {e}"))?,
            ),
            None => None,
        };
        let beat_every = Duration::from_millis(self.cfg.heartbeat_ms.max(1));
        let mut last_beat = Duration::ZERO;
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for req in trace {
                // Open loop: wait until the request's arrival time.
                let due = Duration::from_micros(req.arrival_us);
                if let Some(wait) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(wait);
                }
                if let Some(f) = heartbeat.as_mut() {
                    let elapsed = t0.elapsed();
                    if elapsed.saturating_sub(last_beat) >= beat_every {
                        last_beat = elapsed;
                        self.emit_heartbeat(f, elapsed, &metrics);
                    }
                }
                let cands =
                    if seg_on { crate::workload::candidate_set(wl, &req) } else { Vec::new() };
                let metrics = &metrics;
                let threshold = self.cfg.long_threshold;
                let seed = self.cfg.seed ^ req.rid();
                scope.spawn(move || {
                    let mut rng = Rng::new(seed);
                    match self.drive_request_with(req, &cands, &mut rng) {
                        Ok(lc) => {
                            let mut m = metrics.lock().unwrap();
                            m.record(&lc, req.plen() > threshold);
                        }
                        Err(e) => log::warn!("request {} failed: {e:#}", req.id),
                    }
                });
            }
        });
        // Final heartbeat: every request has completed (scope joined), so
        // this line mirrors the end-of-run stats block.
        if let Some(f) = heartbeat.as_mut() {
            self.emit_heartbeat(f, t0.elapsed(), &metrics);
        }
        let mut m = metrics.into_inner().unwrap();
        m.sim_duration_us = t0.elapsed().as_micros() as u64;
        let elapsed = m.sim_duration_us.max(1) as f64;
        m.util = self
            .instances
            .iter()
            .map(|i| {
                (i.busy_us.load(Ordering::Relaxed) as f64
                    / (elapsed * self.cfg.m_slots as f64))
                    .min(1.0)
            })
            .collect();
        {
            let mut cells = self.shared.cells.lock().unwrap();
            let per = self.shared.inst_per_cell;
            // Specials reported by global instance id; stats merged in
            // cell-index order for determinism.
            m.special_instances = (0..cells.n_cells())
                .flat_map(|c| {
                    cells.coord(c).special_instances().iter().map(move |&i| c * per + i)
                })
                .collect();
            m.hbm = cells.coord(0).hbm_stats();
            m.hierarchy = cells.coord(0).hierarchy_stats();
            m.trigger = cells.coord(0).trigger_stats();
            m.segments = cells.coord(0).segment_stats();
            m.faults = cells.coord(0).fault_report();
            for c in 1..cells.n_cells() {
                m.hbm.merge(cells.coord(c).hbm_stats());
                m.hierarchy.merge(cells.coord(c).hierarchy_stats());
                m.trigger.merge(cells.coord(c).trigger_stats());
                m.segments.merge(cells.coord(c).segment_stats());
                m.faults.merge(&cells.coord(c).fault_report());
            }
            m.cells = cells.reports();
            if let Some(fl) = cells.take_flight() {
                m.stages = fl.breakdown.clone();
                m.flight = Some(Arc::new(fl));
            }
        }
        Ok(m)
    }

    pub fn shutdown(self) {
        for inst in self.instances {
            inst.stop();
        }
    }

    pub fn uptime(&self) -> Duration {
        self.start.elapsed()
    }
}

fn sleep_us(us: f64) {
    if us > 0.0 {
        std::thread::sleep(Duration::from_micros(us as u64));
    }
}
