//! Live serving engine: the relay-race coordinator running for real —
//! worker threads per instance, real PJRT executions (the AOT artifacts),
//! device-resident ψ buffers in an HBM window, host-memory DRAM tier,
//! wall-clock metrics.
//!
//! This is the same control logic as the simulator (identical `relay::*`
//! state machines) driving actual compute, used by the examples, by
//! `relaygr serve`, and by `relaygr calibrate` to fit the simulator's CPU
//! cost profile.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::RunMetrics;
use crate::model::ModelSpec;
use crate::relay::baseline::Mode;
use crate::relay::expander::{DramPolicy, Expander, PseudoAction};
use crate::relay::hbm::HbmCache;
use crate::relay::pipeline::{CacheOutcome, Lifecycle, PipelineConfig, StageSampler};
use crate::relay::router::{Router, RouterConfig};
use crate::relay::trigger::{BehaviorMeta, Decision, Trigger, TriggerConfig};
use crate::runtime::{synth_embedding, Engine, FnKind, KvBuffer, LoadedModel};
use crate::util::rng::Rng;
use crate::workload::{GenRequest, WorkloadConfig};

/// Cache payload: device-resident in HBM, host copy in the DRAM tier.
#[derive(Clone)]
pub enum Payload {
    Device(Arc<KvBuffer>),
    Host(Arc<Vec<f32>>),
}

/// Live-engine configuration.
#[derive(Clone)]
pub struct LiveConfig {
    pub artifacts_dir: String,
    pub spec: ModelSpec,
    pub mode: Mode,
    pub n_instances: usize,
    pub m_slots: usize,
    /// HBM window per instance (bytes of ψ).
    pub hbm_bytes: usize,
    pub max_reload_concurrency: usize,
    pub long_threshold: usize,
    pub pipeline: PipelineConfig,
    /// Scale factor on retrieval/preproc sleeps (1.0 = production-mirror).
    pub stage_scale: f64,
    /// Wait budget for ψ production before falling back (µs).
    pub wait_budget_us: u64,
    pub seed: u64,
}

impl LiveConfig {
    pub fn new(artifacts_dir: &str, spec: ModelSpec, mode: Mode) -> LiveConfig {
        LiveConfig {
            artifacts_dir: artifacts_dir.to_string(),
            spec,
            mode,
            n_instances: 2,
            m_slots: 2,
            hbm_bytes: 256 << 20,
            max_reload_concurrency: 2,
            long_threshold: spec.prefix_len.saturating_sub(1),
            pipeline: PipelineConfig::default(),
            stage_scale: 1.0,
            wait_budget_us: 200_000,
            seed: 42,
        }
    }
}

enum Work {
    PreInfer { user: u64 },
    Rank { req: GenRequest, issued: Instant, resp: Sender<RankDone> },
    Stop,
}

struct RankDone {
    outcome: CacheOutcome,
    rank_us: f64,
    load_us: f64,
    wait_us: f64,
    scores: Vec<f32>,
}

struct InstanceState {
    hbm: HbmCache<Payload>,
    expander: Expander<Payload>,
    /// Users whose ψ production failed (evicted/lost) since last check.
    produce_failed: HashMap<u64, u64>,
    pre_done: u64,
}

/// One live ranking instance: m_slots worker threads over a shared queue.
pub struct LiveInstance {
    pub id: usize,
    tx: Sender<Work>,
    state: Arc<(Mutex<InstanceState>, Condvar)>,
    workers: Vec<std::thread::JoinHandle<()>>,
    busy_us: Arc<AtomicU64>,
}

struct Models {
    prefix: Arc<LoadedModel>,
    rank: Arc<LoadedModel>,
    full: Arc<LoadedModel>,
}

impl LiveInstance {
    fn spawn(id: usize, cfg: &LiveConfig, models: Arc<Models>) -> LiveInstance {
        let dram = match cfg.mode {
            Mode::RelayGr { dram } => dram,
            _ => DramPolicy::Disabled,
        };
        let state = Arc::new((
            Mutex::new(InstanceState {
                hbm: HbmCache::new(cfg.hbm_bytes),
                expander: Expander::new(dram, cfg.max_reload_concurrency),
                produce_failed: HashMap::new(),
                pre_done: 0,
            }),
            Condvar::new(),
        ));
        let (tx, rx) = channel::<Work>();
        let rx = Arc::new(Mutex::new(rx));
        let busy_us = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::new();
        for _ in 0..cfg.m_slots {
            let rx = rx.clone();
            let state = state.clone();
            let models = models.clone();
            let cfg = cfg.clone();
            let busy = busy_us.clone();
            workers.push(std::thread::spawn(move || loop {
                let work = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match work {
                    Ok(Work::PreInfer { user }) => {
                        Self::do_pre_infer(user, &cfg, &models, &state, &busy);
                    }
                    Ok(Work::Rank { req, issued, resp }) => {
                        let done = Self::do_rank(&req, issued, &cfg, &models, &state, &busy);
                        let _ = resp.send(done);
                    }
                    Ok(Work::Stop) | Err(_) => break,
                }
            }));
        }
        LiveInstance { id, tx, state, workers, busy_us }
    }

    /// The pre-infer signal handler (§3.2): pseudo-check, then compute ψ
    /// and keep it device-resident.
    fn do_pre_infer(
        user: u64,
        cfg: &LiveConfig,
        models: &Models,
        state: &Arc<(Mutex<InstanceState>, Condvar)>,
        busy: &Arc<AtomicU64>,
    ) {
        let (lock, cv) = &**state;
        let kv_bytes = cfg.spec.kv_bytes();
        // Pseudo-pre-infer: skip when already resident / reloadable.
        let action = {
            let mut guard = lock.lock().unwrap();
            let st = &mut *guard;
            let a = st.expander.pseudo_pre_infer(user, &mut st.hbm, now_us());
            if matches!(a, PseudoAction::Miss) {
                if st.hbm.begin_produce(user, kv_bytes, now_us(), cfg.pipeline.t_life_us).is_err()
                {
                    st.produce_failed.insert(user, now_us());
                    cv.notify_all();
                    return;
                }
            }
            a
        };
        match action {
            PseudoAction::Miss => {
                // Behaviour fetch + embedding + the prefix pass on device.
                let prefix = synth_embedding(user ^ 1, cfg.spec.prefix_len, cfg.spec.dim, 0.5);
                let t0 = Instant::now();
                let result = models.prefix.execute_to_device(&[&prefix]);
                busy.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                let mut st = lock.lock().unwrap();
                match result {
                    Ok(kv) => {
                        st.hbm.complete_produce(user, Payload::Device(Arc::new(kv)));
                    }
                    Err(e) => {
                        log::warn!("pre-infer failed for user {user}: {e:#}");
                        st.produce_failed.insert(user, now_us());
                    }
                }
                st.pre_done += 1;
                cv.notify_all();
            }
            PseudoAction::StartReload { .. } => {
                Self::do_reload(user, cfg, models, state);
            }
            _ => {
                // Already resident / in flight: re-arm the lifecycle for
                // this request (§3.4 pseudo pre-inference semantics).
                let mut st = lock.lock().unwrap();
                st.hbm.extend_lease(user, now_us() + cfg.pipeline.t_life_us);
            }
        }
    }

    /// Perform one DRAM→HBM reload (real H2D) and wake waiters.
    fn do_reload(
        user: u64,
        cfg: &LiveConfig,
        models: &Models,
        state: &Arc<(Mutex<InstanceState>, Condvar)>,
    ) {
        let (lock, cv) = &**state;
        let host = {
            let mut st = lock.lock().unwrap();
            st.expander.dram_payload(user)
        };
        let installed = match host {
            Some((bytes, Payload::Host(data))) => match models.rank.kv_from_host(&data) {
                Ok(kv) => {
                    let mut st = lock.lock().unwrap();
                    let (_joiners, next) = st.expander.finish_reload(user);
                    let ok = st
                        .hbm
                        .insert_ready(
                            user,
                            bytes,
                            Payload::Device(Arc::new(kv)),
                            now_us(),
                            cfg.pipeline.t_life_us,
                        )
                        .is_ok();
                    if !ok {
                        st.produce_failed.insert(user, now_us());
                    }
                    cv.notify_all();
                    if let Some(nu) = next {
                        drop(st);
                        Self::do_reload(nu, cfg, models, state);
                    }
                    ok
                }
                Err(e) => {
                    log::warn!("reload H2D failed for {user}: {e:#}");
                    false
                }
            },
            _ => false,
        };
        if !installed {
            let mut st = lock.lock().unwrap();
            let (_, next) = st.expander.finish_reload(user);
            st.produce_failed.insert(user, now_us());
            cv.notify_all();
            if let Some(nu) = next {
                drop(st);
                Self::do_reload(nu, cfg, models, state);
            }
        }
    }

    fn do_rank(
        req: &GenRequest,
        issued: Instant,
        cfg: &LiveConfig,
        models: &Models,
        state: &Arc<(Mutex<InstanceState>, Condvar)>,
        busy: &Arc<AtomicU64>,
    ) -> RankDone {
        let (lock, cv) = &**state;
        let user = req.user;
        let is_long = cfg.mode.is_relay() && req.prefix_len > cfg.long_threshold;
        let incr = synth_embedding(user ^ 2, cfg.spec.incr_len, cfg.spec.dim, 0.5);
        let items =
            synth_embedding(req.id ^ 3, cfg.spec.num_items, cfg.spec.dim, 0.5);
        let mut load_us = 0.0;
        let mut wait_us = 0.0;
        let mut outcome = CacheOutcome::FullInference;
        let mut kv: Option<Payload> = None;

        if is_long {
            let wait_start = Instant::now();
            let mut st = lock.lock().unwrap();
            loop {
                let stm = &mut *st;
                match stm.expander.pseudo_pre_infer(user, &mut stm.hbm, now_us()) {
                    PseudoAction::HbmHit => {
                        kv = st.hbm.consume(user);
                        outcome = CacheOutcome::HbmHit;
                        break;
                    }
                    PseudoAction::WaitProducing
                    | PseudoAction::JoinReload
                    | PseudoAction::QueuedReload => {
                        if st.produce_failed.remove(&user).is_some() {
                            outcome = CacheOutcome::Fallback;
                            break;
                        }
                        let waited = wait_start.elapsed().as_micros() as u64;
                        if waited > cfg.wait_budget_us {
                            outcome = CacheOutcome::Fallback;
                            break;
                        }
                        let (g, _t) = cv
                            .wait_timeout(st, Duration::from_millis(5))
                            .expect("condvar poisoned");
                        st = g;
                    }
                    PseudoAction::StartReload { .. } => {
                        // Perform the H2D inline on this worker (it holds a
                        // reload-concurrency slot).
                        drop(st);
                        let t0 = Instant::now();
                        Self::do_reload(user, cfg, models, state);
                        load_us = t0.elapsed().as_micros() as f64;
                        st = lock.lock().unwrap();
                        if let Some(p) = st.hbm.consume(user) {
                            kv = Some(p);
                            outcome = CacheOutcome::DramHit;
                        } else {
                            outcome = CacheOutcome::Fallback;
                        }
                        break;
                    }
                    PseudoAction::Miss => {
                        outcome = if req.is_refresh {
                            CacheOutcome::Fallback
                        } else {
                            CacheOutcome::FullInference
                        };
                        break;
                    }
                }
            }
            wait_us = wait_start.elapsed().as_micros() as f64 - load_us;
        }

        // Execute ranking.
        let t0 = Instant::now();
        let scores = match (&kv, outcome) {
            (Some(Payload::Device(buf)), _) => {
                models.rank.execute_with_kv(buf, &[&incr, &items]).unwrap_or_default()
            }
            _ => {
                let prefix = synth_embedding(user ^ 1, cfg.spec.prefix_len, cfg.spec.dim, 0.5);
                models.full.execute_host(&[&prefix, &incr, &items]).unwrap_or_default()
            }
        };
        let rank_us = t0.elapsed().as_micros() as f64;
        busy.fetch_add(rank_us as u64, Ordering::Relaxed);

        // Spill fresh ψ to DRAM (D2H) and slide the HBM window.
        if let (Some(Payload::Device(buf)), CacheOutcome::HbmHit) = (&kv, outcome) {
            if cfg.mode.is_relay() {
                if let Ok(host) = buf.to_host() {
                    let mut st = lock.lock().unwrap();
                    st.expander.spill(user, buf.bytes, Payload::Host(Arc::new(host)));
                    st.hbm.evict(user);
                }
            }
        } else if let (Some(Payload::Device(_)), CacheOutcome::DramHit) = (&kv, outcome) {
            let mut st = lock.lock().unwrap();
            st.hbm.evict(user); // still in DRAM; window slides
        }
        let _ = issued;
        RankDone { outcome, rank_us, load_us, wait_us, scores }
    }

    fn stop(self) {
        let _ = self.tx.send(Work::Stop);
        for _ in 1..self.workers.len() {
            let _ = self.tx.send(Work::Stop);
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn now_us() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap().as_micros() as u64
}

/// The live cluster: router + per-special-instance triggers + instances.
pub struct LiveCluster {
    pub cfg: LiveConfig,
    engine: Arc<Engine>,
    instances: Vec<LiveInstance>,
    router: Mutex<Router>,
    triggers: Mutex<HashMap<usize, Trigger>>,
    start: Instant,
}

impl LiveCluster {
    pub fn start(cfg: LiveConfig) -> Result<LiveCluster> {
        let engine = Arc::new(Engine::load(&cfg.artifacts_dir)?);
        let models = Arc::new(Models {
            prefix: engine.model(FnKind::Prefix, &cfg.spec)?,
            rank: engine.model(FnKind::Rank, &cfg.spec)?,
            full: engine.model(FnKind::Full, &cfg.spec)?,
        });
        let is_baseline = matches!(cfg.mode, Mode::Baseline);
        let router = Router::new(RouterConfig {
            n_instances: cfg.n_instances,
            servers: cfg.n_instances,
            r2: if is_baseline { 0.0 } else { (1.0 / cfg.n_instances as f64).max(0.45) },
            max_special_per_server: 1,
            gateways: 2,
            vnodes: 32,
            normal_policy: crate::relay::router::BalancePolicy::LeastConnections,
        })?;
        let tcfg = TriggerConfig {
            rank_p99_budget_us: cfg.pipeline.rank_budget_us,
            headroom: 0.8,
            t_life_us: cfg.pipeline.t_life_us,
            kv_p99_bytes: cfg.spec.kv_bytes(),
            hbm_bytes: cfg.hbm_bytes,
            r1: 1.0,
            q_m: 1000.0,
            m_slots: cfg.m_slots,
            r2: 0.5,
            n_instances: cfg.n_instances,
        };
        let threshold = cfg.long_threshold;
        let mut triggers = HashMap::new();
        for &i in router.special_instances() {
            let est: crate::relay::trigger::Estimator = Box::new(move |m: &BehaviorMeta| {
                // Live risk test: long prefixes are at risk by construction.
                if m.prefix_len > threshold {
                    1e9
                } else {
                    0.0
                }
            });
            triggers.insert(i, Trigger::new(tcfg.clone(), est));
        }
        let instances =
            (0..cfg.n_instances).map(|id| LiveInstance::spawn(id, &cfg, models.clone())).collect();
        Ok(LiveCluster {
            cfg,
            engine,
            instances,
            router: Mutex::new(router),
            triggers: Mutex::new(triggers),
            start: Instant::now(),
        })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Drive one request through retrieval → preproc → ranking with real
    /// sleeps and real execution; returns its lifecycle.
    pub fn drive_request(&self, req: GenRequest, rng: &mut Rng) -> Result<Lifecycle> {
        let t0 = Instant::now();
        let is_long = self.cfg.mode.is_relay() && req.prefix_len > self.cfg.long_threshold;
        let mut admitted = false;
        if is_long {
            // Trigger side path (metadata only).
            let inst = {
                let mut r = self.router.lock().unwrap();
                let route = r.route_special(req.user);
                r.on_complete(route.instance);
                route.instance
            };
            let meta = BehaviorMeta {
                user: req.user,
                prefix_len: req.prefix_len,
                dim: self.cfg.spec.dim,
            };
            let decision = self
                .triggers
                .lock()
                .unwrap()
                .get_mut(&inst)
                .map(|t| t.decide(now_us(), &meta))
                .unwrap_or(Decision::NotAtRisk);
            if decision == Decision::Admit {
                admitted = true;
                let _ = self.instances[inst].tx.send(Work::PreInfer { user: req.user });
            }
        }
        let retrieval = StageSampler::from_mean_p99(
            self.cfg.pipeline.retrieval_mean_us,
            self.cfg.pipeline.retrieval_p99_us,
        );
        let preproc = StageSampler::from_mean_p99(
            self.cfg.pipeline.preproc_mean_us,
            self.cfg.pipeline.preproc_p99_us,
        );
        sleep_us(retrieval.sample(rng) * self.cfg.stage_scale);
        let retrieval_done = t0.elapsed().as_micros() as u64;
        sleep_us(preproc.sample(rng) * self.cfg.stage_scale);
        let preproc_done = t0.elapsed().as_micros() as u64;

        let inst = {
            let mut r = self.router.lock().unwrap();
            let route = if is_long { r.route_special(req.user) } else { r.route_normal(req.user) };
            route.instance
        };
        let (tx, rx): (Sender<RankDone>, Receiver<RankDone>) = channel();
        self.instances[inst]
            .tx
            .send(Work::Rank { req, issued: Instant::now(), resp: tx })
            .map_err(|_| anyhow!("instance {inst} stopped"))?;
        let done = rx.recv().map_err(|_| anyhow!("rank worker dropped response"))?;
        {
            let mut r = self.router.lock().unwrap();
            r.on_complete(inst);
        }
        if admitted {
            if let Some(t) = self.triggers.lock().unwrap().values_mut().next() {
                t.release();
            }
        }
        let done_us = t0.elapsed().as_micros() as u64;
        anyhow::ensure!(!done.scores.is_empty(), "empty scores from rank execution");
        Ok(Lifecycle {
            request: req.id,
            user: req.user,
            prefix_len: req.prefix_len,
            arrival_us: 0,
            retrieval_done_us: retrieval_done,
            preproc_done_us: preproc_done,
            rank_start_us: preproc_done,
            done_us,
            pre_us: 0.0,
            load_us: done.load_us,
            rank_us: done.rank_us,
            wait_us: done.wait_us,
            outcome: done.outcome,
            admitted,
            instance: inst,
        })
    }

    /// Run a whole trace open-loop; returns aggregated metrics.
    pub fn run_trace(&self, wl: &WorkloadConfig) -> Result<RunMetrics> {
        let trace = crate::workload::generate(wl);
        let metrics = Mutex::new(RunMetrics::new(self.cfg.pipeline.pipeline_slo_us));
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for req in trace {
                // Open loop: wait until the request's arrival time.
                let due = Duration::from_micros(req.arrival_us);
                if let Some(wait) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(wait);
                }
                let metrics = &metrics;
                let threshold = self.cfg.long_threshold;
                let seed = self.cfg.seed ^ req.id;
                scope.spawn(move || {
                    let mut rng = Rng::new(seed);
                    match self.drive_request(req, &mut rng) {
                        Ok(lc) => {
                            let mut m = metrics.lock().unwrap();
                            m.record(&lc, req.prefix_len > threshold);
                        }
                        Err(e) => log::warn!("request {} failed: {e:#}", req.id),
                    }
                });
            }
        });
        let mut m = metrics.into_inner().unwrap();
        m.sim_duration_us = t0.elapsed().as_micros() as u64;
        let elapsed = m.sim_duration_us.max(1) as f64;
        m.util = self
            .instances
            .iter()
            .map(|i| {
                (i.busy_us.load(Ordering::Relaxed) as f64
                    / (elapsed * self.cfg.m_slots as f64))
                    .min(1.0)
            })
            .collect();
        m.special_instances = self.router.lock().unwrap().special_instances().to_vec();
        for inst in &self.instances {
            let st = inst.state.0.lock().unwrap();
            let _ = st.pre_done;
        }
        Ok(m)
    }

    pub fn shutdown(self) {
        for inst in self.instances {
            inst.stop();
        }
    }

    pub fn uptime(&self) -> Duration {
        self.start.elapsed()
    }
}

fn sleep_us(us: f64) {
    if us > 0.0 {
        std::thread::sleep(Duration::from_micros(us as u64));
    }
}
