//! `relaygr serve` — live serving demo: real PJRT executables behind the
//! relay-race coordinator, driven by a synthetic trace, reporting
//! wall-clock latency/throughput and cache behaviour.

use anyhow::{anyhow, Result};

use crate::config;
use crate::metrics::OUTCOME_NAMES;
use crate::relay::tier::TierConfig;
use crate::runtime::Manifest;
use crate::serve::engine::{LiveCluster, LiveConfig};
use crate::util::cli::Args;
use crate::workload::{ScenarioKind, WorkloadConfig};

pub fn run(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let mode = config::parse_mode(args.get_or("mode", "relaygr+dram8g"))?;
    let manifest = Manifest::load(&dir)?;
    let spec = match args.get("variant") {
        Some(name) => manifest
            .artifacts
            .iter()
            .find(|a| a.spec.name() == name)
            .map(|a| a.spec)
            .ok_or_else(|| anyhow!("no variant '{name}' (see `relaygr inspect`)"))?,
        None => manifest.live_variant().ok_or_else(|| anyhow!("no artifacts"))?,
    };
    let mut cfg = LiveConfig::new(&dir, spec, mode);
    cfg.n_instances = args.get_usize("instances", cfg.n_instances)?;
    cfg.m_slots = args.get_usize("slots", cfg.m_slots)?;
    cfg.stage_scale = args.get_f64("stage-scale", cfg.stage_scale)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    if let Some(p) = args.get("dram-policy") {
        cfg.dram_policy = config::parse_policy(p)?;
    }
    if let Some(t) = args.get("tier") {
        cfg.tiers = Some(config::parse_tiers(t)?);
    }
    cfg.segment_frac = config::parse_segment_frac(args, cfg.segment_frac)?;
    cfg.admission = config::parse_admission(args, &cfg.admission)?;
    cfg.batch_window_us = args.get_u64("batch-window", cfg.batch_window_us)?;
    cfg.batch_max = args.get_usize("batch-max", cfg.batch_max)?;
    if cfg.batch_max == 0 {
        return Err(anyhow!(
            "--batch-max must be >= 1 (use --batch-window 0 to disable batching)"
        ));
    }
    cfg.cells = args.get_usize("cells", cfg.cells)?;
    if let Some(p) = args.get("cell-picker") {
        cfg.cell_picker = crate::relay::cell::CellPickerKind::parse(p)?;
    }
    cfg.cell_spill = args.get_f64("cell-spill", cfg.cell_spill)?;
    if cfg.cell_spill <= 0.0 {
        return Err(anyhow!(
            "--cell-spill must be > 0 (use inf for pure locality), got {}",
            cfg.cell_spill
        ));
    }
    if let Some(s) = args.get("faults") {
        cfg.faults = crate::relay::fault::FaultConfig::parse(s)?;
    }
    cfg.trace_spans = args.get_usize("trace-spans", cfg.trace_spans)?;
    cfg.heartbeat_path = args.get("heartbeat").map(str::to_string);
    cfg.heartbeat_ms = args.get_u64("heartbeat-ms", cfg.heartbeat_ms)?;
    if cfg.heartbeat_ms == 0 {
        return Err(anyhow!("--heartbeat-ms must be >= 1"));
    }

    let scenario = match args.get("scenario") {
        Some(s) => ScenarioKind::parse(s).map_err(|e| anyhow!(e))?,
        None => ScenarioKind::Steady,
    };
    // Scenario-shaped initial operating point for the adaptive
    // controller (explicit --headroom-init / --rate-mult-init win).
    let profile = scenario.admission_profile();
    cfg.admission.seed_operating_point(profile.headroom_init, profile.rate_mult_init);
    let mut wl = WorkloadConfig {
        qps: args.get_f64("qps", 20.0)?,
        duration_us: (args.get_f64("duration-s", 10.0)? * 1e6) as u64,
        num_users: args.get_u64("users", 500)?,
        long_frac: args.get_f64("long-frac", 0.5)?,
        long_threshold: cfg.long_threshold,
        min_prefix: 64,
        max_prefix: spec.prefix_len,
        fixed_long_len: Some(spec.prefix_len),
        refresh_prob: args.get_f64("refresh-prob", 0.4)?,
        scenario,
        seed: cfg.seed,
        ..Default::default()
    };
    config::apply_candidate_flags(args, &mut wl)?;

    let tier_desc = cfg
        .tier_stack()
        .iter()
        .map(TierConfig::label)
        .collect::<Vec<_>>()
        .join(",");
    println!(
        "serving {} on {} instance(s) × {} slot(s) in {} cell(s), mode {}, tiers [{}], \
         scenario {}, admission {}, qps {}, {}s",
        spec.name(),
        cfg.n_instances,
        cfg.m_slots,
        cfg.cells,
        mode.label(),
        if tier_desc.is_empty() { "hbm-only" } else { &tier_desc },
        wl.scenario.label(),
        cfg.admission.label(),
        wl.qps,
        wl.duration_us / 1_000_000
    );
    let cluster = LiveCluster::start(cfg)?;
    // Warm-up: compile + first-execution costs out of the measurement.
    let mut rng = crate::util::rng::Rng::new(1);
    let warm = crate::workload::generate(&WorkloadConfig {
        qps: 10.0,
        duration_us: 400_000,
        ..wl.clone()
    });
    for req in warm.into_iter().take(4) {
        let _ = cluster.drive_request(req, &mut rng);
    }

    let m = cluster.run_trace(&wl)?;
    println!("\n{}", m.brief());
    println!("  e2e        {}", m.e2e.summary().fmt_ms());
    println!("  rank stage {}", m.rank_stage.summary().fmt_ms());
    println!("  rank exec  {}", m.rank_exec.summary().fmt_ms());
    if m.load.count() > 0 {
        println!("  dram load  {}", m.load.summary().fmt_ms());
    }
    if m.wait.count() > 0 {
        println!("  ψ wait     {}", m.wait.summary().fmt_ms());
    }
    println!(
        "  outcomes   {}",
        m.outcome_counts
            .iter()
            .zip(OUTCOME_NAMES)
            .map(|(c, n)| format!("{n}={c}"))
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!(
        "  success    {:.4} (SLO {} ms)   util {:.0}%",
        m.success_rate(),
        m.pipeline_slo_us / 1e3,
        m.mean_util(None) * 100.0
    );
    for line in m.tier_report() {
        println!("  {line}");
    }
    for line in m.cells_report() {
        println!("  {line}");
    }
    for line in m.faults_report() {
        println!("  {line}");
    }
    if let Some(line) = m.admission_brief() {
        println!("  {line}");
    }
    if let Some(fl) = m.flight.as_deref() {
        println!(
            "  spans      {} emitted, {} retained, {} dropped",
            fl.emitted(),
            fl.retained(),
            fl.dropped()
        );
        if let Some(path) = args.get("trace-out") {
            let (n, bytes) = fl.write_rgsp(path)?;
            println!("  wrote {n} spans ({bytes} bytes) to {path}");
        }
    }
    cluster.shutdown();
    Ok(())
}
