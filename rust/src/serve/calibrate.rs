//! `relaygr calibrate` — measure live PJRT execution costs over the
//! artifact grid and fit the simulator's CPU hardware profile, writing
//! `results/calibration.json`.  This closes the loop between live
//! measurements and the discrete-event cost model (DESIGN.md
//! §Substitutions).

use anyhow::Result;

use crate::model::HardwareProfile;
use crate::runtime::{synth_embedding, Engine, FnKind};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats::Online;

pub fn run(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let reps = args.get_usize("reps", 5)?;
    let engine = Engine::load(dir)?;
    let mut rows = Vec::new();
    let mut eff = Online::default();
    println!(
        "{:<40} {:>12} {:>12} {:>12} {:>14}",
        "variant", "pre_ms", "rank_ms", "full_ms", "eff_gflops"
    );
    for spec in engine.manifest.variants() {
        let (Ok(prefix_m), Ok(rank_m), Ok(full_m)) = (
            engine.model(FnKind::Prefix, &spec),
            engine.model(FnKind::Rank, &spec),
            engine.model(FnKind::Full, &spec),
        ) else {
            continue;
        };
        let prefix = synth_embedding(1, spec.prefix_len, spec.dim, 0.5);
        let incr = synth_embedding(2, spec.incr_len, spec.dim, 0.5);
        let items = synth_embedding(3, spec.num_items, spec.dim, 0.5);
        // Warm up once (first execution includes lazy initialisation).
        let kv = prefix_m.execute_to_device(&[&prefix])?;
        let _ = rank_m.execute_with_kv(&kv, &[&incr, &items])?;
        let _ = full_m.execute_host(&[&prefix, &incr, &items])?;

        let mut pre_t = Online::default();
        let mut rank_t = Online::default();
        let mut full_t = Online::default();
        for _ in 0..reps {
            let t = std::time::Instant::now();
            let kv = prefix_m.execute_to_device(&[&prefix])?;
            pre_t.push(t.elapsed().as_secs_f64() * 1e6);
            let t = std::time::Instant::now();
            let _ = rank_m.execute_with_kv(&kv, &[&incr, &items])?;
            rank_t.push(t.elapsed().as_secs_f64() * 1e6);
            let t = std::time::Instant::now();
            let _ = full_m.execute_host(&[&prefix, &incr, &items])?;
            full_t.push(t.elapsed().as_secs_f64() * 1e6);
        }
        // Effective FLOP rate from the full pass (the sturdiest estimate).
        let flops = spec.full_flops(spec.prefix_len);
        let gflops = flops / full_t.mean() / 1e3;
        eff.push(flops / full_t.mean());
        println!(
            "{:<40} {:>12.2} {:>12.2} {:>12.2} {:>14.2}",
            spec.name(),
            pre_t.mean() / 1e3,
            rank_t.mean() / 1e3,
            full_t.mean() / 1e3,
            gflops
        );
        let mut row = Json::obj();
        row.set("variant", spec.name().as_str().into())
            .set("pre_us", pre_t.mean().into())
            .set("rank_us", rank_t.mean().into())
            .set("full_us", full_t.mean().into())
            .set("flops_full", flops.into())
            .set("eff_flops_per_us", (flops / full_t.mean()).into());
        rows.push(row);
    }
    anyhow::ensure!(!rows.is_empty(), "no complete variants found in {dir}");

    let fitted = eff.mean();
    let profile = HardwareProfile::cpu_live();
    println!(
        "\nfitted cpu eff_flops_per_us = {fitted:.0} (profile default {:.0}); \
         simulator cross-check: rank_full({}) model {:.1} ms",
        profile.eff_flops_per_us,
        engine.manifest.variants()[0].name(),
        profile.rank_full_us(&engine.manifest.variants()[0], engine.manifest.variants()[0].prefix_len) / 1e3,
    );
    let out_dir = args.get_or("results", "results");
    std::fs::create_dir_all(out_dir)?;
    let mut j = Json::obj();
    j.set("fitted_eff_flops_per_us", fitted.into())
        .set("platform", engine.platform().as_str().into())
        .set("rows", Json::Arr(rows));
    let path = format!("{out_dir}/calibration.json");
    std::fs::write(&path, j.to_string_pretty())?;
    println!("wrote {path}");
    Ok(())
}
