//! Live serving engine: the relay-race coordinator over real PJRT
//! executions (threads + condvars instead of the simulator's virtual
//! clock), plus the `serve` and `calibrate` CLI entry points.

pub mod calibrate;
pub mod cli;
pub mod engine;

pub use engine::{LiveCluster, LiveConfig, Payload};
