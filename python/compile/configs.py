"""Model-variant grid for the RelayGR reproduction.

Each :class:`ModelConfig` describes one GR backbone variant (the paper's
Type 1 = HSTU, Type 2 = HSTU with revised attention, Type 3 = a
LONGER-style cached backbone feeding a RankMixer-style DLRM tower).

For every config three entry points are AOT-lowered to HLO text:

* ``prefix``  — pre-inference over the long-term behaviour prefix,
  producing the per-layer KV cache ψ (the paper's cached object).
* ``rank``    — ranking-on-cache: consumes ψ plus the incremental tokens
  (short-term behaviours + cross features) and the candidate items.
* ``full``    — the production baseline: full inline inference.

Sequence-length *buckets* are static shapes (PJRT AOT requires static
shapes); the rust coordinator picks the smallest bucket that fits a
request, exactly as production serving stacks bucket their inputs.
"""

from dataclasses import dataclass, field, asdict
from typing import List


# Block size used by the Pallas attention kernel.  All sequence buckets,
# incremental lengths and candidate counts must be multiples of this.
BLOCK = 64


@dataclass(frozen=True)
class ModelConfig:
    """One GR backbone variant (static-shape bucket)."""

    model_type: int  # 1 = HSTU, 2 = HSTU-rev, 3 = LONGER+RankMixer-style
    layers: int
    dim: int
    heads: int
    prefix_len: int      # S_l : long-term behaviour prefix tokens
    incr_len: int        # S~l : short-term behaviours + cross features
    num_items: int       # |I| : candidate items scored per request
    dtype: str = "float32"
    seed: int = 0

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def total_len(self) -> int:
        return self.prefix_len + self.incr_len + self.num_items

    @property
    def items_start(self) -> int:
        return self.prefix_len + self.incr_len

    @property
    def kv_bytes(self) -> int:
        """ψ footprint in bytes: per-layer K and V over the prefix.

        Table 1 sanity: 8 layers, 2K tokens, dim 256, fp32
        -> 8 * 2 * 2048 * 256 * 4 B = 32 MiB.
        """
        itemsize = 4 if self.dtype == "float32" else 2
        return self.layers * 2 * self.prefix_len * self.dim * itemsize

    @property
    def name(self) -> str:
        return (
            f"t{self.model_type}_L{self.layers}_D{self.dim}_H{self.heads}"
            f"_S{self.prefix_len}_I{self.incr_len}_N{self.num_items}"
        )

    def validate(self) -> None:
        for v, what in [
            (self.prefix_len, "prefix_len"),
            (self.incr_len, "incr_len"),
            (self.num_items, "num_items"),
        ]:
            if v % BLOCK != 0 or v <= 0:
                raise ValueError(f"{what}={v} must be a positive multiple of {BLOCK}")
        if self.dim % self.heads != 0:
            raise ValueError("dim must be divisible by heads")
        if self.model_type not in (1, 2, 3):
            raise ValueError("model_type must be 1, 2 or 3")


# ---------------------------------------------------------------------------
# Default artifact grid.
#
# Live-mode (real PJRT CPU execution) uses small dims so that `make
# artifacts` stays fast; the rust discrete-event simulator covers the
# paper-scale dims (256..1024, 8..16 layers, up to 15K tokens) through the
# calibrated cost model.
# ---------------------------------------------------------------------------

def default_grid() -> List[ModelConfig]:
    grid: List[ModelConfig] = []
    # Sequence-length scaling family (Type 1 = HSTU-style).
    for prefix in (256, 512, 1024, 2048):
        grid.append(ModelConfig(1, 2, 64, 2, prefix, 64, 128))
    # A deeper/wider config for the end-to-end example.
    grid.append(ModelConfig(1, 4, 128, 4, 512, 64, 128))
    # Candidate-set scaling (Fig. 14a live calibration).
    grid.append(ModelConfig(1, 2, 64, 2, 512, 64, 256))
    # Model generality (Fig. 15a): Type 2 and Type 3 variants.
    grid.append(ModelConfig(2, 2, 64, 2, 512, 64, 128))
    grid.append(ModelConfig(3, 2, 64, 2, 512, 64, 128))
    for cfg in grid:
        cfg.validate()
    return grid


def tiny() -> ModelConfig:
    """Smallest config — used by unit tests and the quickstart example."""
    return ModelConfig(1, 2, 32, 2, 128, 64, 64)


def config_to_dict(cfg: ModelConfig) -> dict:
    d = asdict(cfg)
    d["head_dim"] = cfg.head_dim
    d["kv_bytes"] = cfg.kv_bytes
    d["name"] = cfg.name
    return d
