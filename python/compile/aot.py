"""AOT lowering: jax → HLO **text** artifacts + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from the ``python/`` directory, as `make artifacts` does)::

    python -m compile.aot --out-dir ../artifacts [--grid default|tiny]

Python runs only here, at build time; the rust coordinator loads the
resulting artifacts via PJRT and never touches python on the request
path.  Re-running is a no-op when inputs are unchanged (the Makefile
guards on source mtimes).
"""

import argparse
import hashlib
import json
import os
import sys
import time
from typing import List

import jax
from jax._src.lib import xla_client as xc

from . import model
from .configs import ModelConfig, config_to_dict, default_grid, tiny

FNS = ("prefix", "rank", "full")


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser).

    ``return_tuple=False``: every entry point returns exactly one array
    (ψ or the score vector), so the module root is the raw array.  The
    rust hot path can then keep ψ as an on-device PjRtBuffer and feed it
    straight back into the rank executable via ``execute_b`` — the
    in-HBM residency the paper's relay race relies on — without a host
    round-trip or tuple unpacking.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    # CRITICAL: the default HLO printer elides large literals as
    # `constant({...})`, which the text parser silently re-materialises as
    # ZEROS — the baked model weights would vanish.  Print with
    # print_large_constants so the artifact is self-contained.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The consumer is xla_extension 0.5.1's HLO parser, which predates
    # newer metadata attributes (source_end_line etc.) — strip metadata.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    if "{...}" in text:
        raise RuntimeError("HLO still contains elided constants")
    return text


def lower_entry(cfg: ModelConfig, fn: str) -> str:
    specs = model.input_specs(cfg, fn)
    lowered = jax.jit(model.entry(cfg, fn)).lower(*specs)
    return to_hlo_text(lowered)


def artifact_record(cfg: ModelConfig, fn: str, path: str, hlo: str) -> dict:
    specs = model.input_specs(cfg, fn)
    out_shapes = {
        "prefix": [[cfg.layers, 2, cfg.heads, cfg.prefix_len, cfg.head_dim]],
        "rank": [[cfg.num_items]],
        "full": [[cfg.num_items]],
    }[fn]
    return {
        "name": f"{fn}_{cfg.name}",
        "fn": fn,
        "path": os.path.basename(path),
        "sha256": hashlib.sha256(hlo.encode()).hexdigest(),
        "config": config_to_dict(cfg),
        "inputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
        ],
        "outputs": [{"shape": s, "dtype": "float32"} for s in out_shapes],
    }


def build(out_dir: str, grid: List[ModelConfig], verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    records = []
    t_start = time.time()
    for cfg in grid:
        cfg.validate()
        for fn in FNS:
            name = f"{fn}_{cfg.name}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            t0 = time.time()
            hlo = lower_entry(cfg, fn)
            with open(path, "w") as f:
                f.write(hlo)
            records.append(artifact_record(cfg, fn, path, hlo))
            if verbose:
                print(
                    f"  {name:48s} {len(hlo) / 1e6:7.2f} MB hlo  "
                    f"{time.time() - t0:5.1f}s",
                    flush=True,
                )
    manifest = {
        "version": 1,
        "generated_unix": int(time.time()),
        "jax_version": jax.__version__,
        "artifacts": records,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(
            f"wrote {len(records)} artifacts + manifest.json "
            f"in {time.time() - t_start:.1f}s"
        )
    return manifest


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--grid", choices=("default", "tiny"), default="default")
    args = ap.parse_args(argv)
    grid = default_grid() if args.grid == "default" else [tiny()]
    build(args.out_dir, grid)


if __name__ == "__main__":
    main()
