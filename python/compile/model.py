"""L2: the GR ranking model f — an HSTU-style generative backbone plus a
task tower — with three entry points mirroring the paper's formalisation

    ψ ← f([U, S_l, ∅, ∅], ∅)                     (prefix_forward)
    scores = f([∅, ∅, S~l, I], ψ)                 (rank_forward)
    scores = f([U, S_l, S~l, I], ∅)               (full_forward)

with the ε-bound  |full − rank∘prefix| ≤ ε  checked by pytest and by the
rust integration tests.

The backbone stacks HSTU blocks::

    x̂   = rms_norm(x)
    q,k,v,u = x̂ W_q, x̂ W_k, x̂ W_v, x̂ W_u          (per-head split)
    a   = hstu_attention(q, k_cat, v_cat)           (L1 Pallas kernel)
    y   = rms_norm(a) ⊙ silu(u)
    x   = x + y W_o

ψ is the per-layer (K, V) of the behaviour prefix: [L, 2, H, S_l, dh].
Cache correctness rests on K/V being functions of the *prefix tokens
only* (candidates never write into behaviour rows — enforced by the
relay-race mask), so the cached and recomputed values are identical.

Weights are generated from a fixed seed at trace time and baked into the
HLO as constants: the rust request path then needs no weight plumbing,
matching the "artifact = self-contained model variant" contract.

Model types:
  1 — HSTU (SiLU pointwise attention), MLP task tower.
  2 — HSTU-rev: identical except sigmoid attention ("differs only in its
      attention computation", §4.4).
  3 — LONGER-style cached backbone + a RankMixer-style DLRM tower (deeper
      MLP with a feature-mixing layer); only the backbone is cached,
      matching "for Type 3 we cache only the Longer component".
"""

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.hstu_attention import hstu_attention


class LayerParams(NamedTuple):
    wq: jax.Array  # [D, D]
    wk: jax.Array
    wv: jax.Array
    wu: jax.Array
    wo: jax.Array


class TowerParams(NamedTuple):
    ws: Tuple[jax.Array, ...]  # MLP weights; last maps to scalar
    w_mix: Optional[jax.Array]  # Type-3 feature-mixing matrix or None


class Params(NamedTuple):
    layers: Tuple[LayerParams, ...]
    tower: TowerParams


def init_params(cfg: ModelConfig) -> Params:
    """Deterministic weight init (fixed seed ⇒ reproducible artifacts)."""
    key = jax.random.PRNGKey(cfg.seed + 1000 * cfg.model_type)
    d = cfg.dim
    scale = 1.0 / d**0.5
    layers: List[LayerParams] = []
    for _ in range(cfg.layers):
        key, *ks = jax.random.split(key, 6)
        layers.append(
            LayerParams(*(jax.random.normal(k, (d, d), jnp.float32) * scale for k in ks))
        )
    if cfg.model_type == 3:
        # RankMixer-style: deeper tower + token/feature mixing.
        widths = [d, 4 * d, 4 * d, 1]
        key, km = jax.random.split(key)
        w_mix = jax.random.normal(km, (d, d), jnp.float32) * scale
    else:
        widths = [d, 2 * d, 1]
        w_mix = None
    ws = []
    for a, b in zip(widths[:-1], widths[1:]):
        key, k = jax.random.split(key)
        ws.append(jax.random.normal(k, (a, b), jnp.float32) * (1.0 / a**0.5))
    return Params(tuple(layers), TowerParams(tuple(ws), w_mix))


def rms_norm(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)


def _split_heads(x: jax.Array, heads: int) -> jax.Array:
    s, d = x.shape
    return x.reshape(s, heads, d // heads).transpose(1, 0, 2)  # [H, S, dh]


def _merge_heads(x: jax.Array) -> jax.Array:
    h, s, dh = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * dh)


def backbone(
    tokens: jax.Array,
    params: Params,
    cfg: ModelConfig,
    kv_in: Optional[jax.Array],
    q_offset: int,
) -> Tuple[jax.Array, jax.Array]:
    """Run the HSTU stack over ``tokens`` (the *new* rows).

    Args:
      tokens: [S_new, D] pre-embedded input rows.
      kv_in: optional cached ψ [L, 2, H, S_prev, dh]; K/V are concatenated
        in front of this call's K/V so new rows attend over the full span.
      q_offset: global index of tokens[0] (= S_prev).

    Returns (hidden [S_new, D], kv_out [L, 2, H, S_new, dh]).
    """
    h = tokens
    kv_out = []
    for li, p in enumerate(params.layers):
        xn = rms_norm(h)
        q = _split_heads(xn @ p.wq, cfg.heads)
        k = _split_heads(xn @ p.wk, cfg.heads)
        v = _split_heads(xn @ p.wv, cfg.heads)
        u = xn @ p.wu
        kv_out.append(jnp.stack([k, v]))
        if kv_in is not None:
            k = jnp.concatenate([kv_in[li, 0], k], axis=1)
            v = jnp.concatenate([kv_in[li, 1], v], axis=1)
        a = hstu_attention(
            q,
            k,
            v,
            q_offset=q_offset,
            items_start=cfg.items_start,
            total_len=cfg.total_len,
            model_type=cfg.model_type,
        )
        y = rms_norm(_merge_heads(a)) * jax.nn.silu(u)
        h = h + y @ p.wo
    return h, jnp.stack(kv_out)


def tower(h_items: jax.Array, params: Params, cfg: ModelConfig) -> jax.Array:
    """Task tower: per-candidate hidden → score logit [N_items]."""
    x = h_items
    if cfg.model_type == 3 and params.tower.w_mix is not None:
        # RankMixer-style feature mixing across the embedding dimension.
        x = x + jax.nn.silu(x @ params.tower.w_mix)
    for i, w in enumerate(params.tower.ws):
        x = x @ w
        if i + 1 < len(params.tower.ws):
            x = jax.nn.silu(x)
    return x[:, 0]


# ---------------------------------------------------------------------------
# Entry points (one HLO artifact each).
# ---------------------------------------------------------------------------

def prefix_forward(cfg: ModelConfig, params: Params, prefix_tokens: jax.Array):
    """Pre-inference: behaviour prefix [S_l, D] → ψ [L, 2, H, S_l, dh]."""
    _, kv = backbone(prefix_tokens, params, cfg, kv_in=None, q_offset=0)
    return (kv,)


def rank_forward(
    cfg: ModelConfig,
    params: Params,
    kv: jax.Array,
    incr_tokens: jax.Array,
    item_tokens: jax.Array,
):
    """Ranking-on-cache: ψ + incremental + candidates → scores [N]."""
    new_tokens = jnp.concatenate([incr_tokens, item_tokens], axis=0)
    h, _ = backbone(new_tokens, params, cfg, kv_in=kv, q_offset=cfg.prefix_len)
    h_items = h[cfg.incr_len :]
    return (tower(h_items, params, cfg),)


def full_forward(
    cfg: ModelConfig,
    params: Params,
    prefix_tokens: jax.Array,
    incr_tokens: jax.Array,
    item_tokens: jax.Array,
):
    """Baseline: full inline inference → scores [N]."""
    tokens = jnp.concatenate([prefix_tokens, incr_tokens, item_tokens], axis=0)
    h, _ = backbone(tokens, params, cfg, kv_in=None, q_offset=0)
    h_items = h[cfg.items_start :]
    return (tower(h_items, params, cfg),)


def input_specs(cfg: ModelConfig, fn: str):
    """ShapeDtypeStructs for jit.lower, in artifact parameter order."""
    f32 = jnp.float32
    d, dh = cfg.dim, cfg.head_dim
    specs = {
        "prefix": [jax.ShapeDtypeStruct((cfg.prefix_len, d), f32)],
        "rank": [
            jax.ShapeDtypeStruct((cfg.layers, 2, cfg.heads, cfg.prefix_len, dh), f32),
            jax.ShapeDtypeStruct((cfg.incr_len, d), f32),
            jax.ShapeDtypeStruct((cfg.num_items, d), f32),
        ],
        "full": [
            jax.ShapeDtypeStruct((cfg.prefix_len, d), f32),
            jax.ShapeDtypeStruct((cfg.incr_len, d), f32),
            jax.ShapeDtypeStruct((cfg.num_items, d), f32),
        ],
    }
    return specs[fn]


def entry(cfg: ModelConfig, fn: str):
    """Bind cfg+params into a positional function ready for jit.lower."""
    params = init_params(cfg)
    fns = {
        "prefix": lambda *xs: prefix_forward(cfg, params, *xs),
        "rank": lambda *xs: rank_forward(cfg, params, *xs),
        "full": lambda *xs: full_forward(cfg, params, *xs),
    }
    return fns[fn]
