"""L1 Pallas kernel: HSTU-style pointwise attention for generative
recommendation, with full-sequence and KV-cached (relay-race) variants.

HSTU attention (Zhai et al., "Actions Speak Louder than Words") replaces
softmax with a pointwise nonlinearity::

    A = phi(Q K^T / sqrt(d_h)) * M / n        (phi = SiLU for Type 1,
    O = A V                                    sigmoid for Type 2 "rev")

Because there is no row-wise softmax there is no running-max/denominator
rescaling: the output is a plain sum over key blocks, so the kernel tiles
(q-block × k-block) and *accumulates* into the output ref across the key
grid dimension.  This is the TPU-idiomatic reformulation of the paper's
Ascend-cube kernel: the BlockSpec grid expresses the HBM↔VMEM schedule
that a GPU/NPU kernel would express with threadblocks.

The attention mask is computed **inside the kernel** from global row/col
indices (broadcasted_iota) instead of materialising an S×S mask in HBM:

* behaviour rows (global row < items_start): causal — ``col <= row``;
* candidate-item rows (global row >= items_start): attend to every
  behaviour token plus themselves, but *not* to other candidates —
  ``col < items_start or col == row``.  Candidates are therefore scored
  independently, which is what makes the per-layer KV of the behaviour
  prefix a reusable cache object ψ.

The cached variant is the same kernel with ``q_offset > 0``: the query
rows are the incremental tokens (short-term + cross features + items)
whose global indices start after the cached prefix, and K/V span
[prefix ‖ incremental].

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU perf is estimated in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import BLOCK


def _phi(x, model_type: int):
    """Pointwise attention nonlinearity per model type."""
    if model_type == 2:  # "revised" attention: sigmoid gating
        return jax.nn.sigmoid(x)
    return jax.nn.silu(x)  # Types 1 and 3


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    scale: float,
    inv_n: float,
    bq: int,
    bk: int,
    q_offset: int,
    items_start: int,
    model_type: int,
):
    """One (head, q-block, k-block) grid step.

    Refs carry a leading singleton head axis selected by the index maps:
    q_ref [1, bq, dh], k_ref/v_ref [1, bk, dh], o_ref [1, bq, dh].
    """
    ik = pl.program_id(2)

    q = q_ref[0]  # [bq, dh]
    k = k_ref[0]  # [bk, dh]
    v = v_ref[0]  # [bk, dh]

    # MXU-friendly block matmul in fp32 accumulation.
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    a = _phi(s, model_type)

    # Global indices of this tile's rows/cols.
    iq = pl.program_id(1)
    rows = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    causal = cols <= rows
    item_row = rows >= items_start
    item_ok = (cols < items_start) | (cols == rows)
    mask = jnp.where(item_row, item_ok, causal)

    a = jnp.where(mask, a, 0.0) * inv_n
    contrib = jnp.dot(a.astype(v.dtype), v, preferred_element_type=jnp.float32)

    # Accumulate across the key grid dimension (sequential innermost dim).
    @pl.when(ik == 0)
    def _init():
        o_ref[0] = contrib.astype(o_ref.dtype)

    @pl.when(ik > 0)
    def _acc():
        o_ref[0] = o_ref[0] + contrib.astype(o_ref.dtype)


def hstu_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_offset: int,
    items_start: int,
    total_len: int,
    model_type: int = 1,
    block_q: int = BLOCK,
    block_k: int = BLOCK,
) -> jax.Array:
    """Pointwise-normalised multi-head attention.

    Args:
      q: [H, Sq, dh] query rows (the tokens being computed this call).
      k: [H, Sk, dh] keys spanning [cached prefix ‖ new tokens].
      v: [H, Sk, dh] values, same span as ``k``.
      q_offset: global sequence index of q row 0 (0 for full/prefix
        inference, ``prefix_len`` for ranking-on-cache).
      items_start: global index of the first candidate-item token.
      total_len: S_l + S~l + |I|; the 1/n normaliser uses this so that the
        full and cached computations are bit-comparable.
      model_type: 1/3 = SiLU (HSTU), 2 = sigmoid (revised attention).

    Returns [H, Sq, dh].
    """
    heads, sq, dh = q.shape
    _, sk, _ = k.shape
    if sq % block_q or sk % block_k:
        raise ValueError(f"Sq={sq}/Sk={sk} must be multiples of {block_q}/{block_k}")

    kernel = functools.partial(
        _attn_kernel,
        scale=1.0 / float(dh) ** 0.5,
        inv_n=1.0 / float(total_len),
        bq=block_q,
        bk=block_k,
        q_offset=q_offset,
        items_start=items_start,
        model_type=model_type,
    )
    grid = (heads, sq // block_q, sk // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, sq, dh), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)
