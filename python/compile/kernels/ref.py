"""Pure-jnp correctness oracle for the HSTU attention kernel.

Dense O(S²) reference with an explicitly materialised mask — slow but
obviously correct.  Every pytest kernel case asserts the Pallas kernel
against this.
"""

import jax
import jax.numpy as jnp


def mask_matrix(sq: int, sk: int, q_offset: int, items_start: int) -> jax.Array:
    """[Sq, Sk] boolean relay-race mask (see hstu_attention.py docstring)."""
    rows = q_offset + jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    causal = cols <= rows
    item_row = rows >= items_start
    item_ok = (cols < items_start) | (cols == rows)
    return jnp.where(item_row, item_ok, causal)


def hstu_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_offset: int,
    items_start: int,
    total_len: int,
    model_type: int = 1,
) -> jax.Array:
    """Reference pointwise attention. Shapes as in hstu_attention()."""
    _, sq, dh = q.shape
    _, sk, _ = k.shape
    s = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(dh))
    if model_type == 2:
        a = jax.nn.sigmoid(s)
    else:
        a = jax.nn.silu(s)
    m = mask_matrix(sq, sk, q_offset, items_start)
    a = jnp.where(m[None, :, :], a, 0.0) / jnp.float32(total_len)
    return jnp.einsum("hqk,hkd->hqd", a.astype(v.dtype), v)
