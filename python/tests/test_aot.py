"""AOT path: HLO-text lowering, the large-constant pitfall, and the
manifest contract consumed by the rust runtime."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import config_to_dict, default_grid, tiny


@pytest.fixture(scope="module")
def tiny_build(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), [tiny()], verbose=False)
    return out, manifest


def test_manifest_structure(tiny_build):
    out, manifest = tiny_build
    assert len(manifest["artifacts"]) == 3
    kinds = sorted(a["fn"] for a in manifest["artifacts"])
    assert kinds == ["full", "prefix", "rank"]
    for a in manifest["artifacts"]:
        assert os.path.exists(out / a["path"])
        assert a["config"]["name"] in a["name"]
        assert a["inputs"] and a["outputs"]
        assert len(a["sha256"]) == 64
    # The file written to disk reparses.
    with open(out / "manifest.json") as f:
        assert json.load(f)["artifacts"]


def test_hlo_has_no_elided_constants(tiny_build):
    """Regression: the default HLO printer writes weights as
    `constant({...})`, which the rust-side parser re-materialises as
    zeros.  Every artifact must be fully materialised."""
    out, manifest = tiny_build
    for a in manifest["artifacts"]:
        text = open(out / a["path"]).read()
        assert "{...}" not in text, f"{a['name']} has elided constants"
        assert "source_end_line" not in text, "metadata breaks the 0.5.1 parser"
        assert text.startswith("HloModule")


def test_output_shapes_recorded(tiny_build):
    _, manifest = tiny_build
    cfg = tiny()
    by_fn = {a["fn"]: a for a in manifest["artifacts"]}
    assert by_fn["prefix"]["outputs"][0]["shape"] == [
        cfg.layers, 2, cfg.heads, cfg.prefix_len, cfg.head_dim,
    ]
    assert by_fn["rank"]["outputs"][0]["shape"] == [cfg.num_items]
    assert by_fn["full"]["outputs"][0]["shape"] == [cfg.num_items]


def test_hlo_text_reparses_with_intact_shapes(tiny_build):
    """Parse each artifact's HLO text back through XLA's parser and check
    the program shape matches the manifest contract.  (The *numeric*
    round-trip — text → xla_extension 0.5.1 → PJRT execution vs jax — is
    asserted end-to-end by `relaygr selftest` and the rust integration
    tests; this guards the python side against elided/garbled text.)"""
    from jax._src.lib import xla_client as xc

    out, manifest = tiny_build
    cfg = tiny()
    for a in manifest["artifacts"]:
        text = open(out / a["path"]).read()
        hm = xc._xla.hlo_module_from_text(text)  # raises on bad text
        reprinted = hm.to_string()
        # Entry layout must carry the same parameter/result shapes.
        def dims(spec):
            return "[" + ",".join(str(d) for d in spec["shape"]) + "]"
        header = text.splitlines()[0]
        for spec in a["inputs"]:
            assert f"f32{dims(spec)}" in header, (a["name"], header)
        assert f"->f32{dims(a['outputs'][0])}" in header.replace(" ", ""), a["name"]
        assert reprinted.startswith("HloModule")
    # Weights survive the print: a jax re-execution of the entry function
    # must produce nonzero scores (zero scores = zeroed constants).
    params = model.init_params(cfg)
    prefix = jnp.full((cfg.prefix_len, cfg.dim), 0.1, jnp.float32)
    incr = jnp.full((cfg.incr_len, cfg.dim), 0.1, jnp.float32)
    items = jnp.full((cfg.num_items, cfg.dim), 0.1, jnp.float32)
    (want,) = model.full_forward(cfg, params, prefix, incr, items)
    assert float(np.abs(np.asarray(want)).max()) > 1e-3


def test_default_grid_is_valid():
    grid = default_grid()
    assert len(grid) >= 6
    names = [c.name for c in grid]
    assert len(set(names)) == len(names), "duplicate variant names"
    types = {c.model_type for c in grid}
    assert types == {1, 2, 3}, "all three model families present"
    for cfg in grid:
        cfg.validate()
        d = config_to_dict(cfg)
        assert d["kv_bytes"] == cfg.layers * 2 * cfg.prefix_len * cfg.dim * 4


def test_config_validation_errors():
    from compile.configs import ModelConfig

    with pytest.raises(ValueError, match="multiple"):
        ModelConfig(1, 2, 32, 2, 100, 64, 64).validate()
    with pytest.raises(ValueError, match="divisible"):
        ModelConfig(1, 2, 33, 2, 128, 64, 64).validate()
    with pytest.raises(ValueError, match="model_type"):
        ModelConfig(4, 2, 32, 2, 128, 64, 64).validate()
