"""Make the `compile` package importable whether pytest runs from the
repo root (`pytest python/tests/`) or from `python/` (`pytest tests/`)."""

import sys
from pathlib import Path

PKG_ROOT = Path(__file__).resolve().parent.parent  # .../python
if str(PKG_ROOT) not in sys.path:
    sys.path.insert(0, str(PKG_ROOT))
