"""L2 correctness: the paper's ε-bound (cached vs full inference), cache
semantics, shapes and determinism across all three model types."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig, tiny


def make_inputs(cfg, seed=0, scale=0.5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (cfg.prefix_len, cfg.dim), jnp.float32) * scale,
        jax.random.normal(ks[1], (cfg.incr_len, cfg.dim), jnp.float32) * scale,
        jax.random.normal(ks[2], (cfg.num_items, cfg.dim), jnp.float32) * scale,
    )


@pytest.mark.parametrize("model_type", [1, 2, 3])
def test_epsilon_bound_all_types(model_type):
    """|f(full) − f(cached ψ)| ≤ ε — the paper's §2.3 contract."""
    cfg = ModelConfig(model_type, 2, 32, 2, 128, 64, 64)
    params = model.init_params(cfg)
    prefix, incr, items = make_inputs(cfg)
    (full,) = model.full_forward(cfg, params, prefix, incr, items)
    (kv,) = model.prefix_forward(cfg, params, prefix)
    (cached,) = model.rank_forward(cfg, params, kv, incr, items)
    eps = float(np.max(np.abs(np.asarray(full) - np.asarray(cached))))
    assert eps <= 1e-4, f"type {model_type}: ε = {eps}"


def test_kv_shape_matches_table1_arithmetic():
    cfg = tiny()
    params = model.init_params(cfg)
    prefix, _, _ = make_inputs(cfg)
    (kv,) = model.prefix_forward(cfg, params, prefix)
    assert kv.shape == (cfg.layers, 2, cfg.heads, cfg.prefix_len, cfg.head_dim)
    assert kv.size * 4 == cfg.kv_bytes


def test_scores_shape_and_finite():
    cfg = tiny()
    params = model.init_params(cfg)
    prefix, incr, items = make_inputs(cfg)
    (scores,) = model.full_forward(cfg, params, prefix, incr, items)
    assert scores.shape == (cfg.num_items,)
    assert np.isfinite(np.asarray(scores)).all()
    assert float(np.abs(np.asarray(scores)).max()) > 0.0


def test_cache_is_item_independent():
    """ψ must not depend on the candidate set: rank two different item
    sets against one ψ and check each matches its own full inference."""
    cfg = tiny()
    params = model.init_params(cfg)
    prefix, incr, items_a = make_inputs(cfg, seed=0)
    _, _, items_b = make_inputs(cfg, seed=9)
    (kv,) = model.prefix_forward(cfg, params, prefix)
    for items in (items_a, items_b):
        (full,) = model.full_forward(cfg, params, prefix, incr, items)
        (cached,) = model.rank_forward(cfg, params, kv, incr, items)
        np.testing.assert_allclose(np.asarray(full), np.asarray(cached), atol=1e-4)


def test_scores_differ_across_item_sets():
    cfg = tiny()
    params = model.init_params(cfg)
    prefix, incr, items_a = make_inputs(cfg, seed=0)
    _, _, items_b = make_inputs(cfg, seed=9)
    (kv,) = model.prefix_forward(cfg, params, prefix)
    (a,) = model.rank_forward(cfg, params, kv, incr, items_a)
    (b,) = model.rank_forward(cfg, params, kv, incr, items_b)
    assert float(np.max(np.abs(np.asarray(a) - np.asarray(b)))) > 1e-3


def test_params_deterministic_per_config():
    cfg = tiny()
    a = model.init_params(cfg)
    b = model.init_params(cfg)
    np.testing.assert_array_equal(np.asarray(a.layers[0].wq), np.asarray(b.layers[0].wq))
    # Different model types get different weights.
    cfg2 = ModelConfig(2, cfg.layers, cfg.dim, cfg.heads, cfg.prefix_len, cfg.incr_len, cfg.num_items)
    c = model.init_params(cfg2)
    assert float(np.max(np.abs(np.asarray(a.layers[0].wq) - np.asarray(c.layers[0].wq)))) > 0


def test_type3_has_mixing_tower():
    cfg = ModelConfig(3, 2, 32, 2, 128, 64, 64)
    params = model.init_params(cfg)
    assert params.tower.w_mix is not None
    assert len(params.tower.ws) == 3  # deeper RankMixer-style MLP
    cfg1 = tiny()
    assert model.init_params(cfg1).tower.w_mix is None


def test_long_prefix_influences_scores():
    """The long-term prefix must actually matter for ranking (otherwise
    caching it would be pointless)."""
    cfg = tiny()
    params = model.init_params(cfg)
    prefix_a, incr, items = make_inputs(cfg, seed=0)
    prefix_b = prefix_a.at[: cfg.prefix_len // 2].set(-prefix_a[: cfg.prefix_len // 2])
    (sa,) = model.full_forward(cfg, params, prefix_a, incr, items)
    (sb,) = model.full_forward(cfg, params, prefix_b, incr, items)
    assert float(np.max(np.abs(np.asarray(sa) - np.asarray(sb)))) > 1e-3


def test_input_specs_match_entry_arity():
    cfg = tiny()
    for fn in ("prefix", "rank", "full"):
        specs = model.input_specs(cfg, fn)
        entry = model.entry(cfg, fn)
        args = [jnp.zeros(s.shape, s.dtype) for s in specs]
        (out,) = entry(*args)
        assert out.shape is not None
