"""L1 correctness: the Pallas HSTU attention kernel vs the pure-jnp
oracle, including hypothesis sweeps over shapes/offsets/dtypes — the CORE
correctness signal for the compute layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import BLOCK
from compile.kernels.hstu_attention import hstu_attention
from compile.kernels.ref import hstu_attention_ref, mask_matrix


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype) * scale


def run_both(h, sq, sk, dh, q_offset, items_start, total_len, model_type=1, dtype=jnp.float32):
    q = rand(1, (h, sq, dh), dtype)
    k = rand(2, (h, sk, dh), dtype)
    v = rand(3, (h, sk, dh), dtype)
    kw = dict(
        q_offset=q_offset, items_start=items_start, total_len=total_len, model_type=model_type
    )
    out = hstu_attention(q, k, v, **kw)
    ref = hstu_attention_ref(q, k, v, **kw)
    return np.asarray(out), np.asarray(ref)


class TestKernelVsRef:
    def test_full_sequence_causal_plus_items(self):
        # Full inference layout: [prefix | incr | items].
        out, ref = run_both(2, 256, 256, 32, q_offset=0, items_start=192, total_len=256)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_cached_incremental_rows(self):
        # Rank-on-cache: q rows start at the prefix boundary.
        out, ref = run_both(2, 192, 448, 32, q_offset=256, items_start=320, total_len=448)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_prefix_only_pure_causal(self):
        # Pre-inference: no items in range (items_start beyond the span).
        out, ref = run_both(4, 128, 128, 16, q_offset=0, items_start=10_000, total_len=128)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_sigmoid_attention_type2(self):
        out, ref = run_both(2, 128, 128, 32, 0, 64, 128, model_type=2)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_single_head_and_tiny_head_dim(self):
        out, ref = run_both(1, 64, 64, 8, 0, 64, 64)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_bf16_inputs_tolerant(self):
        out, ref = run_both(2, 128, 128, 32, 0, 64, 128, dtype=jnp.bfloat16)
        np.testing.assert_allclose(
            out.astype(np.float32), ref.astype(np.float32), rtol=3e-2, atol=3e-2
        )

    def test_rejects_non_block_multiple(self):
        q = rand(1, (1, BLOCK + 1, 16))
        with pytest.raises(ValueError, match="multiples"):
            hstu_attention(q, q, q, q_offset=0, items_start=0, total_len=65)

    @settings(max_examples=20, deadline=None)
    @given(
        h=st.integers(1, 3),
        sq_blocks=st.integers(1, 4),
        extra_k_blocks=st.integers(0, 4),
        dh=st.sampled_from([8, 16, 32]),
        model_type=st.sampled_from([1, 2, 3]),
        data=st.data(),
    )
    def test_prop_matches_ref(self, h, sq_blocks, extra_k_blocks, dh, model_type, data):
        """Hypothesis sweep: arbitrary block-multiple shapes, offsets and
        item boundaries must all match the dense oracle."""
        sq = sq_blocks * BLOCK
        sk = sq + extra_k_blocks * BLOCK
        q_offset = sk - sq  # cached layout: q rows end at the kv span end
        total_len = sk
        items_start = data.draw(st.integers(0, total_len), label="items_start")
        out, ref = run_both(h, sq, sk, dh, q_offset, items_start, total_len, model_type)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


class TestMaskSemantics:
    def test_behaviour_rows_are_causal(self):
        m = np.asarray(mask_matrix(8, 8, q_offset=0, items_start=6))
        for r in range(6):
            for c in range(8):
                assert m[r, c] == (c <= r)

    def test_item_rows_skip_other_items(self):
        m = np.asarray(mask_matrix(8, 8, q_offset=0, items_start=4))
        for r in range(4, 8):
            for c in range(8):
                expected = c < 4 or c == r
                assert m[r, c] == expected, (r, c)

    def test_items_scored_independently(self):
        """Changing one candidate must not change any other candidate's
        output — the property that makes ψ reusable across item sets."""
        h, s, dh = 2, 128, 16
        items_start = 64
        q = np.asarray(rand(1, (h, s, dh)))
        k = np.asarray(rand(2, (h, s, dh)))
        v = np.asarray(rand(3, (h, s, dh)))
        out1 = np.asarray(
            hstu_attention(jnp.array(q), jnp.array(k), jnp.array(v),
                           q_offset=0, items_start=items_start, total_len=s)
        )
        # Perturb the last item's K/V/Q.
        q2, k2, v2 = q.copy(), k.copy(), v.copy()
        q2[:, -1], k2[:, -1], v2[:, -1] = 9.0, 9.0, 9.0
        out2 = np.asarray(
            hstu_attention(jnp.array(q2), jnp.array(k2), jnp.array(v2),
                           q_offset=0, items_start=items_start, total_len=s)
        )
        # All other item rows unchanged.
        np.testing.assert_allclose(out1[:, items_start:-1], out2[:, items_start:-1], rtol=1e-6)
        # Behaviour rows unchanged too (items never write into behaviours).
        np.testing.assert_allclose(out1[:, :items_start], out2[:, :items_start], rtol=1e-6)


class TestNumerics:
    def test_normalizer_uses_total_len(self):
        out_a, _ = run_both(1, 64, 64, 8, 0, 64, total_len=64)
        out_b, _ = run_both(1, 64, 64, 8, 0, 64, total_len=128)
        np.testing.assert_allclose(out_a, out_b * 2.0, rtol=1e-5)

    def test_zero_inputs_zero_output(self):
        z = jnp.zeros((2, 64, 16), jnp.float32)
        out = hstu_attention(z, z, z, q_offset=0, items_start=64, total_len=64)
        # silu(0) = 0 ⇒ zero attention everywhere.
        assert float(jnp.abs(out).max()) == 0.0

    def test_block_shape_invariance(self):
        """The same computation tiled with different block sizes must agree
        (accumulation order differs only in fp-rounding)."""
        q = rand(1, (2, 256, 16))
        k = rand(2, (2, 256, 16))
        v = rand(3, (2, 256, 16))
        kw = dict(q_offset=0, items_start=192, total_len=256)
        a = hstu_attention(q, k, v, block_q=64, block_k=64, **kw)
        b = hstu_attention(q, k, v, block_q=128, block_k=32, **kw)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
