#!/usr/bin/env bash
# Perf-trajectory tooling: fold the per-suite BENCH_*.json files emitted
# by `cargo bench` into one snapshot under bench/trajectory/, and diff
# two snapshots so regressions show up in the PR log.
#
#   tools/bench_trajectory.sh collect <label> [bench-dir] [out-dir]
#       Reads <bench-dir>/BENCH_*.json (default: rust/) and writes
#       <out-dir>/<label>.json (default: bench/trajectory/).
#
#   tools/bench_trajectory.sh diff <old.json> <new.json>
#       Prints per-bench deltas for mean_us and events_per_sec.  Most
#       deltas are informational (CI runners are noisy) and merely
#       flagged loudly — but a >20% drop in any `simloop` suite
#       events_per_sec against a measured baseline exits nonzero, so a
#       throughput regression on the headline metric fails CI instead
#       of scrolling past.  An unmeasured (`"measured": false`)
#       baseline still exits 0: there is nothing real to gate on.
set -euo pipefail

cmd="${1:-}"
case "$cmd" in
  collect)
    label="${2:?usage: bench_trajectory.sh collect <label> [bench-dir] [out-dir]}"
    bench_dir="${3:-rust}"
    out_dir="${4:-bench/trajectory}"
    mkdir -p "$out_dir"
    python3 - "$label" "$bench_dir" "$out_dir" <<'PY'
import glob, json, os, sys
label, bench_dir, out_dir = sys.argv[1:4]
suites = {}
for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for r in doc.get("results", []):
        row = {k: v for k, v in r.items() if k != "name"}
        rows[r["name"]] = row
    suites[doc.get("suite", os.path.basename(path))] = rows
if not suites:
    sys.exit(f"no BENCH_*.json found under {bench_dir}/ — run `cargo bench` first")
out = {"schema": 1, "label": label, "measured": True, "suites": suites}
dest = os.path.join(out_dir, f"{label}.json")
with open(dest, "w") as f:
    json.dump(out, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"wrote {dest} ({sum(len(v) for v in suites.values())} benches, "
      f"{len(suites)} suites)")
PY
    ;;
  diff)
    old="${2:?usage: bench_trajectory.sh diff <old.json> <new.json>}"
    new="${3:?usage: bench_trajectory.sh diff <old.json> <new.json>}"
    python3 - "$old" "$new" <<'PY'
import json, sys
old_path, new_path = sys.argv[1:3]
def load(p):
    with open(p) as f:
        return json.load(f)
old, new = load(old_path), load(new_path)
if not old.get("measured", True):
    print(f"note: {old_path} is an unmeasured placeholder — no baseline to diff")
    sys.exit(0)
print(f"trajectory diff: {old.get('label')} → {new.get('label')}")
METRICS = [("mean_us", -1), ("events_per_sec", +1)]  # sign: +1 = higher is better
# Hard gate: simloop throughput (the headline events/sec numbers) may
# not drop more than 20% against a measured baseline.  Everything else
# stays informational — shared runners are too noisy to gate on µs.
GATE_SUITE, GATE_METRIC, GATE_DROP_PCT = "simloop", "events_per_sec", -20.0
failures = []
for suite, benches in sorted(new.get("suites", {}).items()):
    base = old.get("suites", {}).get(suite, {})
    for name, row in sorted(benches.items()):
        prev = base.get(name)
        if prev is None:
            print(f"  {suite}/{name}: new bench (no baseline)")
            continue
        for metric, sign in METRICS:
            a, b = prev.get(metric), row.get(metric)
            if a is None or b is None or not a:
                continue
            pct = (b - a) / a * 100.0
            tag = ""
            if suite == GATE_SUITE and metric == GATE_METRIC and pct < GATE_DROP_PCT:
                failures.append(f"{suite}/{name} {metric} {pct:+.1f}%")
                tag = "  <-- REGRESSION (gated)"
            elif sign * pct < -25.0:
                tag = "  <-- REGRESSION"
            print(f"  {suite}/{name} {metric}: {a:.1f} → {b:.1f} ({pct:+.1f}%){tag}")
if failures:
    print(f"FAIL: {len(failures)} gated regression(s) beyond "
          f"{-GATE_DROP_PCT:.0f}%: " + "; ".join(failures))
    sys.exit(1)
PY
    ;;
  *)
    echo "usage: $0 collect <label> [bench-dir] [out-dir] | diff <old.json> <new.json>" >&2
    exit 2
    ;;
esac
