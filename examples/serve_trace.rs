//! End-to-end validation driver (DESIGN.md §End-to-end validation): load
//! the real AOT-compiled GR model, serve a mixed batched trace through
//! the full three-stage pipeline with the live relay-race coordinator,
//! and report latency/throughput for baseline vs RelayGR vs
//! RelayGR+DRAM.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_trace -- \
//!     [--qps 15] [--duration-s 8] [--stage-scale 1.0]
//! ```

use anyhow::{anyhow, Result};

use relaygr::config;
use relaygr::metrics::OUTCOME_NAMES;
use relaygr::relay::baseline::Mode;
use relaygr::relay::tier::DramPolicy;
use relaygr::runtime::Manifest;
use relaygr::serve::{LiveCluster, LiveConfig};
use relaygr::util::cli::Args;
use relaygr::workload::WorkloadConfig;

fn main() -> Result<()> {
    relaygr::util::logging::init();
    let args = Args::from_env().map_err(|e| anyhow!("{e}"))?;
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let manifest = Manifest::load(&dir)?;
    let spec = manifest.live_variant().ok_or_else(|| anyhow!("run `make artifacts`"))?;
    let qps = args.get_f64("qps", 15.0).map_err(|e| anyhow!("{e}"))?;
    let dur_s = args.get_f64("duration-s", 8.0).map_err(|e| anyhow!("{e}"))?;
    let stage_scale = args.get_f64("stage-scale", 1.0).map_err(|e| anyhow!("{e}"))?;

    println!(
        "end-to-end serve_trace: variant {}, qps {qps}, {dur_s}s per mode, stage_scale {stage_scale}",
        spec.name()
    );
    println!(
        "\n{:<18} {:>8} {:>10} {:>10} {:>10} {:>9}  outcomes",
        "mode", "qps", "p50_ms", "p99_ms", "rank_p99", "success"
    );

    let mut baseline_p99 = 0.0;
    for mode in [
        Mode::Baseline,
        Mode::RelayGr { dram: DramPolicy::Disabled },
        Mode::RelayGr { dram: DramPolicy::Capacity(8 << 30) },
    ] {
        let mut cfg = LiveConfig::new(&dir, spec, mode);
        cfg.stage_scale = stage_scale;
        cfg.seed = args.get_u64("seed", 42).map_err(|e| anyhow!("{e}"))?;
        let wl = WorkloadConfig {
            qps,
            duration_us: (dur_s * 1e6) as u64,
            num_users: 300,
            long_frac: 0.5,
            long_threshold: cfg.long_threshold,
            min_prefix: 64,
            max_prefix: spec.prefix_len,
            fixed_long_len: Some(spec.prefix_len),
            refresh_prob: 0.5,
            seed: cfg.seed,
            ..Default::default()
        };
        let cluster = LiveCluster::start(cfg)?;
        // Warm-up to exclude compile/first-run costs.
        let mut rng = relaygr::util::rng::Rng::new(7);
        for req in relaygr::workload::generate(&WorkloadConfig {
            qps: 10.0,
            duration_us: 300_000,
            ..wl.clone()
        })
        .into_iter()
        .take(3)
        {
            let _ = cluster.drive_request(req, &mut rng);
        }
        let m = cluster.run_trace(&wl)?;
        if mode == Mode::Baseline {
            baseline_p99 = m.rank_exec_long.p99();
        }
        println!(
            "{:<18} {:>8.1} {:>10.1} {:>10.1} {:>10.2} {:>9.4}  {}",
            mode.label(),
            m.goodput_qps(),
            m.e2e.p50() / 1e3,
            m.p99_e2e() / 1e3,
            m.rank_stage.p99() / 1e3,
            m.success_rate(),
            m.outcome_counts
                .iter()
                .zip(OUTCOME_NAMES)
                .filter(|(c, _)| **c > 0)
                .map(|(c, n)| format!("{n}:{c}"))
                .collect::<Vec<_>>()
                .join(" "),
        );
        if mode.is_relay() && m.rank_exec_long.count() > 0 {
            println!(
                "{:<18} long-request rank exec p99 {:.2} ms vs baseline {:.2} ms → {:.1}× faster",
                "",
                m.rank_exec_long.p99() / 1e3,
                baseline_p99 / 1e3,
                baseline_p99 / m.rank_exec_long.p99().max(1.0),
            );
        }
        cluster.shutdown();
    }
    // Persist a machine-readable record for EXPERIMENTS.md.
    let mut j = relaygr::util::json::Json::obj();
    j.set("example", "serve_trace".into())
        .set("variant", spec.name().as_str().into())
        .set("qps", qps.into())
        .set("duration_s", dur_s.into());
    std::fs::create_dir_all("results")?;
    std::fs::write("results/serve_trace.json", j.to_string_pretty())?;
    println!("\nserve_trace OK (record: results/serve_trace.json)");
    let _ = config::parse_mode("baseline")?; // exercise public config API
    Ok(())
}
