//! Rapid-refresh demo: the tiered cache hierarchy under out-of-order
//! arrivals and same-user bursts — per-user single-flight, pseudo
//! pre-inference, and at-most-once DRAM→HBM promotion per burst (§3.4),
//! demonstrated against real device buffers.
//!
//! ```bash
//! make artifacts && cargo run --release --example rapid_refresh
//! ```

use std::sync::Arc;

use anyhow::{anyhow, Result};

use relaygr::relay::hierarchy::{CacheHierarchy, PseudoAction};
use relaygr::relay::tier::{EvictPolicy, TierConfig};
use relaygr::runtime::{synth_embedding, Engine, FnKind};
use relaygr::serve::Payload;

fn main() -> Result<()> {
    relaygr::util::logging::init();
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let engine = Engine::load(&dir)?;
    let spec = engine.manifest.default_variant().ok_or_else(|| anyhow!("run `make artifacts`"))?;
    let prefix_m = engine.model(FnKind::Prefix, &spec)?;
    let rank_m = engine.model(FnKind::Rank, &spec)?;

    let mut cache: CacheHierarchy<Payload> = CacheHierarchy::new(
        64 << 20,
        &[TierConfig::new(1 << 30, EvictPolicy::Lru)],
        2,
    );
    let user = 99u64;
    let kv_bytes = spec.kv_bytes();
    let t_life = 300_000;

    // --- request #1: normal relay race ------------------------------------
    println!("request #1: pre-infer → HBM → rank-on-cache");
    let prefix = synth_embedding(user ^ 1, spec.prefix_len, spec.dim, 0.5);
    let incr = synth_embedding(user ^ 2, spec.incr_len, spec.dim, 0.5);
    let items = synth_embedding(user ^ 3, spec.num_items, spec.dim, 0.5);
    cache.hbm_mut().begin_produce(user, kv_bytes, 0, t_life).unwrap();
    let kv = Arc::new(prefix_m.execute_to_device(&[&prefix])?);
    cache.hbm_mut().complete_produce(user, Payload::Device(kv.clone()));
    assert_eq!(cache.pseudo_pre_infer(user, 0), PseudoAction::HbmHit);
    let scores1 = rank_m.execute_with_kv(&kv, &[&incr, &items])?;
    // Consume → demote a host copy into the DRAM tier → window slides.
    cache.hbm_mut().consume(user);
    let host = Arc::new(kv.to_host()?);
    cache.spill(user, kv_bytes, Payload::Host(host));
    cache.hbm_mut().evict(user);
    println!("  ψ demoted to DRAM ({:.2} MB), HBM window slid", kv_bytes as f64 / 1e6);

    // --- rapid refresh burst: 3 out-of-order ranking requests --------------
    println!("\nrapid refresh burst: 3 ranking requests arrive before any pre-infer");
    let a1 = cache.pseudo_pre_infer(user, 0);
    let a2 = cache.pseudo_pre_infer(user, 0);
    let a3 = cache.pseudo_pre_infer(user, 0);
    println!("  pseudo-pre-infer: {a1:?}, {a2:?}, {a3:?}");
    assert!(matches!(a1, PseudoAction::StartReload { .. }), "first starts the promotion");
    assert_eq!(a2, PseudoAction::JoinReload, "second joins");
    assert_eq!(a3, PseudoAction::JoinReload, "third joins");

    // The single promotion performs the only H2D of the burst.
    let t0 = std::time::Instant::now();
    let Some((bytes, Payload::Host(data))) = cache.payload_below(user) else {
        anyhow::bail!("payload vanished")
    };
    let kv2 = Arc::new(rank_m.kv_from_host(&data)?);
    let h2d = t0.elapsed();
    let done = cache.complete_reload(user, Payload::Device(kv2.clone()), bytes, 10, t_life);
    println!(
        "  one H2D promotion ({h2d:.2?}) served {} joined waiters; installed={}",
        done.joiners, done.installed
    );
    assert_eq!(done.joiners, 2);
    assert_eq!(cache.stats().reloads_started, 1, "at most one promotion per burst");

    // All three rank on the promoted ψ — scores must match request #1
    // bit-for-bit (same prefix ⇒ same ψ ⇒ same scores).
    for i in 0..3 {
        let scores = rank_m.execute_with_kv(&kv2, &[&incr, &items])?;
        let eps = scores1
            .iter()
            .zip(&scores)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("  refresh rank #{i}: ε vs request #1 = {eps:.3e}");
        assert!(eps <= 1e-5, "spill/promotion must preserve ψ exactly");
    }

    let s = cache.stats();
    println!(
        "\nhierarchy stats: dram_hits={} joins={} promotions={} demotions={}",
        s.dram_hits, s.reloads_joined, s.reloads_started, s.spills
    );
    println!("rapid_refresh OK");
    Ok(())
}
