//! Quickstart: one relay-race request end to end against real AOT
//! artifacts.
//!
//! Demonstrates the core contract of the paper's formalisation:
//!
//! ```text
//! ψ ← f([U, S_l, ∅, ∅], ∅)            (prefix pre-inference)
//! |f([U,S_l,S̃_l,I], ∅) − f([∅,∅,S̃_l,I], ψ)| ≤ ε
//! ```
//!
//! Run after `make artifacts`:
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::{bail, Result};

use relaygr::runtime::{synth_embedding, Engine, FnKind};

fn main() -> Result<()> {
    relaygr::util::logging::init();
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let engine = Engine::load(&dir)?;
    println!("platform: {}", engine.platform());

    let Some(spec) = engine.manifest.default_variant() else {
        bail!("no artifacts in '{dir}' — run `make artifacts`");
    };
    println!(
        "variant {} — {} layers, dim {}, prefix {}, {} candidates, ψ = {:.2} MB",
        spec.name(),
        spec.layers,
        spec.dim,
        spec.prefix_len,
        spec.num_items,
        spec.kv_bytes() as f64 / 1e6
    );

    // Synthetic user: long-term behaviours, short-term tokens, candidates.
    let user = 4217u64;
    let prefix = synth_embedding(user ^ 1, spec.prefix_len, spec.dim, 0.5);
    let incr = synth_embedding(user ^ 2, spec.incr_len, spec.dim, 0.5);
    let items = synth_embedding(user ^ 3, spec.num_items, spec.dim, 0.5);

    // Baseline: full inline inference (what the production pipeline runs
    // on the ranking critical path today).
    let full_m = engine.model(FnKind::Full, &spec)?;
    let prefix_m = engine.model(FnKind::Prefix, &spec)?;
    let rank_m = engine.model(FnKind::Rank, &spec)?;
    // Warm up all three executables so timings exclude first-run costs.
    let _ = full_m.execute_host(&[&prefix, &incr, &items])?;
    let warm_kv = prefix_m.execute_to_device(&[&prefix])?;
    let _ = rank_m.execute_with_kv(&warm_kv, &[&incr, &items])?;

    let t = std::time::Instant::now();
    let baseline_scores = full_m.execute_host(&[&prefix, &incr, &items])?;
    let t_full = t.elapsed();
    let t = std::time::Instant::now();
    let kv = prefix_m.execute_to_device(&[&prefix])?; // retrieval-time side path
    let t_pre = t.elapsed();
    let t = std::time::Instant::now();
    let relay_scores = rank_m.execute_with_kv(&kv, &[&incr, &items])?; // ranking
    let t_rank = t.elapsed();

    let eps = baseline_scores
        .iter()
        .zip(&relay_scores)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("\n  full inference      : {t_full:8.2?}   (critical path, baseline)");
    println!("  prefix pre-inference: {t_pre:8.2?}   (relay path, off critical)");
    println!("  ranking on ψ        : {t_rank:8.2?}   (critical path, RelayGR)");
    println!(
        "  critical-path speedup: {:.2}×",
        t_full.as_secs_f64() / t_rank.as_secs_f64()
    );
    println!("  ε = max|full − cached| = {eps:.3e}");

    let mut top: Vec<(usize, f32)> = relay_scores.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("\n  top-5 ranked candidates:");
    for (idx, score) in top.iter().take(5) {
        println!("    item {idx:4}  score {score:+.4}");
    }
    if eps > 1e-3 {
        bail!("ε-bound violated: {eps}");
    }
    println!("\nquickstart OK");
    Ok(())
}
