//! Capacity planner: the sequence-aware trigger's admission algebra
//! (Eqs. 1–3) as an operator-facing tool, cross-checked against the
//! discrete-event simulator.
//!
//! ```bash
//! cargo run --release --example capacity_planner
//! ```

use relaygr::cluster::SimConfig;
use relaygr::relay::baseline::Mode;
use relaygr::relay::tier::DramPolicy;
use relaygr::relay::trigger::TriggerConfig;
use relaygr::workload::WorkloadConfig;

fn plan(label: &str, cfg: &TriggerConfig) {
    let lim = cfg.limits();
    println!("\nscenario: {label}");
    println!(
        "  HBM {:.0} GB (r1={}) kv_p99 {:.2} GB T_life {:.0} ms Qm {:.1} M {} r2 {} N {}",
        cfg.hbm_bytes as f64 / 1e9,
        cfg.r1,
        cfg.kv_p99_bytes as f64 / 1e9,
        cfg.t_life_us as f64 / 1e3,
        cfg.q_m,
        cfg.m_slots,
        cfg.r2,
        cfg.n_instances
    );
    println!(
        "  → L_max {:>5} live caches   Q_admit {:>7.1} q/s/instance   \
         specials {:>3}   Q_max {:>8.1} q/s system",
        lim.l_max, lim.q_admit_max, lim.specials, lim.q_max_system
    );
}

fn main() -> anyhow::Result<()> {
    relaygr::util::logging::init();

    // 1. The paper's §3.2 sanity-check numbers (L ≤ 160, 150 q/s, 1500 q/s).
    let paper = TriggerConfig::paper_example();
    plan("paper §3.2 sanity check", &paper);
    let lim = paper.limits();
    assert_eq!(lim.l_max, 160);
    assert_eq!(lim.specials, 10);
    println!("  matches paper: L≤160, Q_admit≤150 q/s, pool Q_max≤1500 q/s ✓");

    // 2. Survivability-bound regime: big caches, long lifecycle.
    let mut tight = paper.clone();
    tight.kv_p99_bytes = 500_000_000; // 0.5 GB ψ (≈ 15K tokens, 1024-dim)
    tight.t_life_us = 600_000;
    plan("long-sequence heavy (0.5 GB ψ, 600 ms lifecycle)", &tight);

    // 3. Compute-bound regime: slow NPU, many slots.
    let mut slow = paper.clone();
    slow.q_m = 7.0;
    slow.m_slots = 8;
    plan("compute-bound (Qm=7 q/s/slot, M=8)", &slow);

    // 4. Cross-check the algebra against the simulator: offered long-
    //    sequence load beyond Q_max must surface as rate/footprint
    //    limiting, never as HBM overcommit (lost caches ≈ 0).
    println!("\nsimulator cross-check (offered ≫ Q_max ⇒ bounded admission, no lost caches):");
    let cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
    let wl = WorkloadConfig {
        qps: 1500.0,
        duration_us: 8_000_000,
        num_users: 50_000,
        fixed_long_len: Some(4096),
        max_prefix: 4096,
        ..Default::default()
    };
    let m = relaygr::cluster::run_sim(cfg, &wl)?;
    println!(
        "  assessed {}  admitted {}  rate-limited {}  footprint-limited {}  lost {}",
        m.trigger.assessed,
        m.trigger.admitted,
        m.trigger.rate_limited,
        m.trigger.footprint_limited,
        m.hbm.lost
    );
    assert!(m.trigger.rate_limited + m.trigger.footprint_limited > 0, "overload must be shed");
    assert_eq!(m.hbm.lost, 0, "admission control must never overcommit HBM");
    println!("\ncapacity_planner OK");
    Ok(())
}
