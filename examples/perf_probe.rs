// Perf probe: sim event-loop throughput + HBM churn with large windows.
use std::time::Instant;
fn main() {
    // (a) simulator wall-clock per simulated second at high load
    for qps in [300.0, 2000.0] {
        let cfg = relaygr::cluster::SimConfig::standard(relaygr::relay::baseline::Mode::RelayGr {
            dram: relaygr::relay::tier::DramPolicy::Capacity(500 << 30),
        });
        let wl = relaygr::workload::WorkloadConfig {
            qps, duration_us: 10_000_000, num_users: 100_000, ..Default::default()
        };
        let t0 = Instant::now();
        let m = relaygr::cluster::run_sim(cfg, &wl).unwrap();
        let dt = t0.elapsed();
        println!("sim qps={qps}: {} reqs in {dt:?} → {:.0} req/s wall, {:.1} µs/req",
            m.completed, m.completed as f64 / dt.as_secs_f64(),
            dt.as_secs_f64()*1e6 / m.completed as f64);
    }
    // (b) HBM cache with a large live window (10k entries): produce/evict churn
    let mut hbm: relaygr::relay::hbm::HbmCache<u32> = relaygr::relay::hbm::HbmCache::new(1 << 40);
    for u in 0..10_000u64 { let _ = hbm.begin_produce(u, 1 << 20, 0, u64::MAX); hbm.complete_produce(u, 0); }
    let t0 = Instant::now();
    let n = 100_000;
    for i in 0..n { let u = 10_000 + i as u64; let _ = hbm.begin_produce(u, 1<<20, 1, u64::MAX); hbm.complete_produce(u,0); hbm.consume(u); hbm.evict(u); }
    println!("hbm churn with 10k resident: {:.2} µs/op", t0.elapsed().as_secs_f64()*1e6/n as f64);
}
